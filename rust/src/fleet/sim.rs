//! The fleet simulator: mixed multi-tenant traffic over many slices, with
//! programming campaigns interleaved — `repro fleet-sim`.
//!
//! The simulation runs on a *simulated* clock: seeded Poisson arrivals per
//! tenant, deterministic routing ([`super::router::FleetRouter`]), and
//! per-request service times from each tenant's placed
//! [`crate::coordinator::BankScheduler`] cost model — so a given seed
//! reproduces the report bit-for-bit (pinned by `rust/tests/fleet.rs`).
//! Optionally it also drives real [`crate::coordinator::Server`] instances
//! (threads + mpsc) to exercise the live serving stack.

use crate::cache::addr::Geometry;
use crate::cache::controller::{CacheController, PimIntegration};
use crate::consts::{ARRAY_ROWS, ARRAY_WORDS};
use crate::coordinator::BankScheduler;
use crate::util::json::Json;
use crate::util::rng::Pcg64;
use crate::util::stats::Summary;
use crate::Result;

use super::campaign::{CampaignReport, CampaignScheduler};
use super::placer::{BankWear, EndurancePlacer, EndurancePolicy, FleetPlacement};
use super::registry::ModelRegistry;
use super::router::{AdmissionController, FleetRouter, ReplicaHealth};

/// Fleet simulation configuration.
#[derive(Clone, Debug)]
pub struct FleetSimConfig {
    /// Slices in the fleet.
    pub n_slices: usize,
    /// Synthetic tenants to generate.
    pub tenants: usize,
    /// Arrival-process seed.
    pub seed: u64,
    /// Requests offered per tenant.
    pub requests_per_tenant: usize,
    /// When reprogramming campaigns start, as a fraction of the expected
    /// traffic horizon (so they interleave with live traffic).
    pub campaign_at_frac: f64,
    /// Also push a small request wave through real
    /// [`crate::coordinator::Server`] instances (threads; wall-clock, so
    /// excluded from the deterministic report fields).
    pub live_serving: bool,
    /// Worker-pool width for the live pass's executors (`fleet-sim
    /// --threads`). The simulated-clock report is analytic and unaffected;
    /// live-pass predictions are bit-identical at any width
    /// ([`crate::pim::parallel`]), so this only changes live throughput.
    pub parallelism: crate::pim::parallel::Parallelism,
    /// Also register the over-capacity wide-ResNet tenant
    /// ([`ModelRegistry::wide_tenant`]), whose replica cannot fit one
    /// slice — forcing the placer onto the shard-parallel path so the
    /// report exercises chain routing and per-hop transfer attribution
    /// (`fleet-sim --no-wide` disables it).
    pub wide_tenant: bool,
    /// Also register the two standard transformer tenants
    /// ([`ModelRegistry::with_transformers`]: `tfm-tiny-d64`,
    /// `tfm-base-d128`), making the default scenario a mixed
    /// CNN+transformer fleet with per-tenant attribution for both
    /// families (`fleet-sim --no-tfm` disables it).
    pub transformer_tenants: bool,
}

impl Default for FleetSimConfig {
    fn default() -> Self {
        FleetSimConfig {
            n_slices: 8,
            tenants: 3,
            seed: 42,
            requests_per_tenant: 400,
            campaign_at_frac: 0.5,
            live_serving: false,
            parallelism: crate::pim::parallel::Parallelism::serial(),
            wide_tenant: true,
            transformer_tenants: true,
        }
    }
}

impl FleetSimConfig {
    /// The small fixed configuration shared by `repro bench` and the
    /// `cargo bench` fleet section (one definition, so the benchmarked
    /// config and its label cannot drift apart).
    pub fn bench_quick() -> FleetSimConfig {
        FleetSimConfig { requests_per_tenant: 150, ..FleetSimConfig::default() }
    }

    /// Stable benchmark label derived from the config, so relabeling can
    /// never lag a config change.
    pub fn bench_label(&self) -> String {
        format!(
            "fleet_sim_{}t{}{}_{}s_{}req",
            self.tenants,
            if self.wide_tenant { "+w" } else { "" },
            if self.transformer_tenants { "+tfm" } else { "" },
            self.n_slices,
            self.requests_per_tenant
        )
    }
}

/// Per-tenant outcome.
#[derive(Clone, Debug)]
pub struct TenantReport {
    /// Tenant id.
    pub tenant: usize,
    /// Tenant name.
    pub name: String,
    /// Replicas placed.
    pub replicas: usize,
    /// Requests served.
    pub served: u64,
    /// Requests shed by the admission controller.
    pub rejected: u64,
    /// Served requests that missed the deadline.
    pub violations: u64,
    /// Median simulated latency (s).
    pub p50_s: f64,
    /// 99th-percentile simulated latency (s).
    pub p99_s: f64,
    /// Mean simulated latency (s).
    pub mean_s: f64,
    /// Simulated hardware energy attributed to this tenant (J).
    pub energy_j: f64,
    /// MAC ops executed for this tenant.
    pub ops: f64,
    /// QoS deadline (s), echoed for the report.
    pub deadline_s: f64,
    /// Shard segments per replica (1 when replica-parallel).
    pub shards: usize,
    /// Slices hosting replica 0's shard chain, in shard order (empty when
    /// replica-parallel — the whole replica lives on one slice).
    pub shard_slices: Vec<usize>,
    /// Per-request inter-slice activation-hop latency (s); 0 unsharded.
    pub transfer_s: f64,
    /// Total inter-slice transfer energy attributed to this tenant (J);
    /// already included in `energy_j`, broken out for attribution.
    pub transfer_energy_j: f64,
}

impl TenantReport {
    /// Did the tenant meet its violation budget?
    pub fn qos_met(&self, max_violation_frac: f64) -> bool {
        self.served > 0 && self.violations as f64 <= max_violation_frac * self.served as f64
    }
}

/// Summary of the optional live-serving pass.
#[derive(Clone, Copy, Debug)]
pub struct LiveSummary {
    /// Requests submitted across all tenants' servers.
    pub requests: u64,
    /// Responses received.
    pub responses: u64,
    /// Batches executed.
    pub batches: u64,
    /// Weight programs compiled — exactly one per *serving* (tenant,
    /// replica) (replicas with an empty request share skip compiling);
    /// the compiled program is retained across campaign rewarm segments.
    pub compilations: u64,
    /// Serving segments executed (each segment tears the server down and
    /// rebuilds it from the retained program, like a campaign rewarm;
    /// empty segments build no server and are not counted).
    pub segments: u64,
}

/// The full fleet-simulation report.
#[derive(Clone, Debug)]
pub struct FleetReport {
    /// Per-tenant outcomes, in tenant order.
    pub tenants: Vec<TenantReport>,
    /// Simulated makespan (s).
    pub horizon_s: f64,
    /// Aggregate served throughput (req per simulated second).
    pub throughput_rps: f64,
    /// Total simulated energy: serving + programming (J).
    pub total_energy_j: f64,
    /// Total MAC ops.
    pub total_ops: f64,
    /// Campaigns executed mid-traffic.
    pub campaigns: Vec<CampaignReport>,
    /// Total campaign downtime across replicas (s).
    pub downtime_s: f64,
    /// Final per-slice bank wear.
    pub wear: Vec<BankWear>,
    /// All banks within the endurance budget?
    pub wear_ok: bool,
    /// Distinct slices hosting replicas.
    pub slices_used: usize,
    /// Every tenant inside its violation budget?
    pub qos_ok: bool,
    /// The endurance policy `wear_ok` (and the rendered per-slice window
    /// fractions) were judged against.
    pub policy: EndurancePolicy,
    /// Live-serving pass summary (when enabled).
    pub live: Option<LiveSummary>,
}

impl FleetReport {
    /// Human-readable report block.
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        let _ = writeln!(
            s,
            "fleet: {} tenants on {} slices | horizon {:.3} s | {:.1} req/s served | \
             {:.3} mJ | qos {} | wear {}",
            self.tenants.len(),
            self.slices_used,
            self.horizon_s,
            self.throughput_rps,
            self.total_energy_j * 1e3,
            if self.qos_ok { "OK" } else { "VIOLATED" },
            if self.wear_ok { "OK" } else { "EXCEEDED" },
        );
        let _ = writeln!(
            s,
            "{:<14} {:>4} {:>7} {:>6} {:>5} {:>10} {:>10} {:>10} {:>10}",
            "tenant", "reps", "served", "shed", "viol", "p50 ms", "p99 ms", "ddl ms", "energy mJ"
        );
        for t in &self.tenants {
            let _ = writeln!(
                s,
                "{:<14} {:>4} {:>7} {:>6} {:>5} {:>10.3} {:>10.3} {:>10.1} {:>10.3}",
                t.name,
                t.replicas,
                t.served,
                t.rejected,
                t.violations,
                t.p50_s * 1e3,
                t.p99_s * 1e3,
                t.deadline_s * 1e3,
                t.energy_j * 1e3,
            );
        }
        for t in &self.tenants {
            if t.shards > 1 {
                let _ = writeln!(
                    s,
                    "  {} shard chain: {} shards on slices {:?} | hop transfer \
                     {:.4} ms/req ({:.2}% of p50) | {:.4} mJ total",
                    t.name,
                    t.shards,
                    t.shard_slices,
                    t.transfer_s * 1e3,
                    100.0 * t.transfer_s / t.p50_s.max(1e-30),
                    t.transfer_energy_j * 1e3,
                );
            }
        }
        let _ = writeln!(
            s,
            "campaigns: {} | downtime {:.3} ms total",
            self.campaigns.len(),
            self.downtime_s * 1e3
        );
        for c in &self.campaigns {
            let _ = writeln!(
                s,
                "  tenant {} replica {} @ slice {}: drain {:.3} ms, program {:.3} ms, \
                 rewarm {:.3} ms, {} lines displaced",
                c.tenant,
                c.replica,
                c.slice,
                c.drain_s * 1e3,
                c.program_s * 1e3,
                c.rewarm_s * 1e3,
                c.lines_displaced
            );
        }
        for (i, w) in self.wear.iter().enumerate() {
            let programmed = w.cycles.iter().filter(|&&c| c > 0.0).count();
            let _ = writeln!(
                s,
                "slice {i}: {} of {} banks programmed, max {} cycles, min window {:.4}",
                programmed,
                w.cycles.len(),
                w.max_cycles(),
                w.min_window_fraction(&self.policy.model),
            );
        }
        if let Some(live) = &self.live {
            let _ = writeln!(
                s,
                "live pass: {} requests → {} responses in {} batches | \
                 {} programs compiled once, reused over {} rewarm segments",
                live.requests, live.responses, live.batches, live.compilations, live.segments
            );
        }
        s
    }

    /// Machine-readable summary (for `BENCH_*.json` accumulation).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("slices_used", Json::Num(self.slices_used as f64)),
            ("horizon_s", Json::Num(self.horizon_s)),
            ("throughput_rps", Json::Num(self.throughput_rps)),
            ("total_energy_j", Json::Num(self.total_energy_j)),
            ("total_ops", Json::Num(self.total_ops)),
            ("campaigns", Json::Num(self.campaigns.len() as f64)),
            ("downtime_s", Json::Num(self.downtime_s)),
            ("qos_ok", Json::Bool(self.qos_ok)),
            ("wear_ok", Json::Bool(self.wear_ok)),
            (
                "max_bank_cycles",
                Json::Num(self.wear.iter().map(|w| w.max_cycles()).fold(0.0, f64::max)),
            ),
            (
                "tenants",
                Json::Arr(
                    self.tenants
                        .iter()
                        .map(|t| {
                            Json::obj(vec![
                                ("name", Json::Str(t.name.clone())),
                                ("served", Json::Num(t.served as f64)),
                                ("rejected", Json::Num(t.rejected as f64)),
                                ("violations", Json::Num(t.violations as f64)),
                                ("p50_s", Json::Num(t.p50_s)),
                                ("p99_s", Json::Num(t.p99_s)),
                                ("mean_s", Json::Num(t.mean_s)),
                                ("energy_j", Json::Num(t.energy_j)),
                                ("shards", Json::Num(t.shards as f64)),
                                ("transfer_s", Json::Num(t.transfer_s)),
                                ("transfer_energy_j", Json::Num(t.transfer_energy_j)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// The fleet simulator.
pub struct FleetSim;

impl FleetSim {
    /// Campaign-rewarm serving segments each live-pass replica runs: the
    /// server (threads, batcher, executor) is torn down and rebuilt
    /// between segments while the compiled weight program is retained —
    /// so, when every replica has requests to serve,
    /// `compilations == Σ replicas` while
    /// `segments == LIVE_SEGMENTS · Σ replicas`. Replicas or segments
    /// whose request share is empty neither compile nor count.
    pub const LIVE_SEGMENTS: usize = 2;

    /// Run the full simulation for `config`.
    pub fn run(config: &FleetSimConfig) -> Result<FleetReport> {
        if config.tenants == 0 {
            return Err(crate::Error::Config("fleet-sim needs at least 1 tenant".into()));
        }
        if config.n_slices == 0 {
            return Err(crate::Error::Config("fleet-sim needs at least 1 slice".into()));
        }
        let geom = Geometry::default();
        let registry = if config.wide_tenant {
            ModelRegistry::synthetic_with_wide(config.tenants)
        } else {
            ModelRegistry::synthetic(config.tenants)
        };
        let registry =
            if config.transformer_tenants { registry.with_transformers() } else { registry };

        // Endurance-aware placement *first*: the placer (via
        // [`crate::fleet::shard::choose_mode`]) decides replica- vs
        // shard-parallel per tenant, and the committed shard plans drive
        // the cost model below — so costs and placement cannot disagree
        // about where the cuts fall.
        let placer = EndurancePlacer::new(geom, config.n_slices);
        let mut fleet = placer.place(&registry)?;

        // Per-tenant per-request cost model. Replica-parallel tenants:
        // whole-network batch-1 cost on a reference slice. Shard-parallel
        // tenants: the chain's pipeline cost — end-to-end `latency_s`
        // (every stage + every hop) is what a request experiences, while
        // `cycle_s` (the bottleneck stage-or-hop) is what a request
        // *occupies* the chain for once the pipeline is full.
        let mut svc_s = Vec::new();
        let mut occ_s = Vec::new();
        let mut energy_req = Vec::new();
        let mut ops_req = Vec::new();
        let mut transfer_req_s = Vec::new();
        let mut transfer_req_j = Vec::new();
        for tenant in &registry.tenants {
            match &fleet.shard_plans[tenant.id] {
                Some(plan) => {
                    let cost = plan.pipeline_cost(&geom, PimIntegration::Retained, 1)?;
                    svc_s.push(cost.latency_s);
                    occ_s.push(cost.cycle_s);
                    energy_req.push(cost.energy_j);
                    ops_req.push(cost.ops);
                    transfer_req_s.push(cost.transfer_latency_s);
                    transfer_req_j.push(cost.transfer_energy_j);
                }
                None => {
                    let mut sched =
                        BankScheduler::new(tenant.layers(), geom, PimIntegration::Retained)
                            .ok_or_else(|| {
                                crate::Error::Config(format!(
                                    "tenant {} does not fit the reference slice",
                                    tenant.id
                                ))
                            })?;
                    sched.program_network();
                    let c1 = sched.batch_cost(1);
                    svc_s.push(c1.latency_s);
                    occ_s.push(c1.latency_s);
                    energy_req.push(c1.energy_j);
                    ops_req.push(c1.ops);
                    transfer_req_s.push(0.0);
                    transfer_req_j.push(0.0);
                }
            }
        }

        // Physical slices + initial weight programming (wear for this is
        // already recorded by the placer).
        let mut controllers: Vec<CacheController> = (0..config.n_slices)
            .map(|_| CacheController::new(geom, PimIntegration::Retained))
            .collect();
        let mut total_energy = 0.0;
        for r in &fleet.replicas {
            for tile in &r.layout.placements {
                for (bank, sa) in [tile.pos_slot, tile.neg_slot] {
                    let stats = controllers[r.slice].program_campaign(
                        bank,
                        sa,
                        vec![0u8; ARRAY_ROWS * ARRAY_WORDS],
                    );
                    total_energy += stats.energy;
                }
            }
        }
        // Warm each slice with deterministic background cache traffic so
        // mid-run campaigns displace real resident lines — otherwise the
        // rewarm phase of drain → program → rewarm is structurally zero.
        for (si, ctl) in controllers.iter_mut().enumerate() {
            let mut rng = Pcg64::new(config.seed, 500 + si as u64);
            for _ in 0..4096 {
                ctl.read(crate::cache::Address::new(rng.next_u64() % (1u64 << 24)));
            }
        }

        // Seeded arrival processes (Poisson per tenant).
        let mut arrivals: Vec<(f64, usize)> = Vec::new();
        let mut rates = Vec::new();
        for tenant in &registry.tenants {
            let rate = tenant.utilization * tenant.replicas as f64 / svc_s[tenant.id];
            rates.push(rate);
            let mut rng = Pcg64::new(config.seed, 100 + tenant.id as u64);
            let mut t = 0.0;
            for _ in 0..config.requests_per_tenant {
                t += -(1.0 - rng.f64()).ln() / rate;
                arrivals.push((t, tenant.id));
            }
        }
        arrivals.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        // Each tenant's campaign fires midway through *its own* traffic
        // horizon, so every campaign interleaves with that tenant's load.
        let campaign_time: Vec<f64> = registry
            .tenants
            .iter()
            .map(|t| config.campaign_at_frac * config.requests_per_tenant as f64 / rates[t.id])
            .collect();

        // Deterministic traffic + campaign event loop.
        let mut router =
            FleetRouter::new(&registry.tenants.iter().map(|t| t.replicas).collect::<Vec<_>>());
        let mut admission = AdmissionController::new(
            svc_s.clone(),
            registry.tenants.iter().map(|t| t.qos.deadline_s).collect(),
        );
        let mut latencies: Vec<Vec<f64>> = vec![Vec::new(); registry.len()];
        let mut violations = vec![0u64; registry.len()];
        let mut tenant_energy = vec![0.0f64; registry.len()];
        let mut tenant_ops = vec![0.0f64; registry.len()];
        let mut tenant_transfer_j = vec![0.0f64; registry.len()];
        let mut campaigns: Vec<CampaignReport> = Vec::new();
        let mut max_completion = 0.0f64;
        let mut fired = vec![false; registry.len()];
        // Replica 0 of tenant t stays ReplicaHealth::Programming until
        // restore_at[t]; the event loop flips it back to Serving once the
        // simulated clock passes that point, so admission/routing actually
        // observe the outage.
        let mut restore_at: Vec<Option<f64>> = vec![None; registry.len()];
        for &(time, tenant) in &arrivals {
            for t in 0..registry.len() {
                if !fired[t] && time >= campaign_time[t] {
                    fired[t] = true;
                    let reports = Self::fire_campaign(
                        t,
                        &mut fleet,
                        &mut controllers,
                        &mut router,
                        campaign_time[t],
                    );
                    total_energy += reports.iter().map(|r| r.energy_j).sum::<f64>();
                    // Chain segments on distinct slices reprogram
                    // concurrently: the replica is down for the slowest
                    // segment, not the sum.
                    let down =
                        reports.iter().map(|r| r.downtime_s()).fold(0.0f64, f64::max);
                    let end = campaign_time[t] + down;
                    restore_at[t] = Some(end);
                    max_completion = max_completion.max(end);
                    campaigns.extend(reports);
                }
                if let Some(end) = restore_at[t] {
                    if time >= end {
                        router.set_health(t, 0, ReplicaHealth::Serving);
                        restore_at[t] = None;
                    }
                }
            }
            if !admission.admit(&router, tenant, time) {
                continue;
            }
            // admit() guarantees a Serving replica exists, so assign()
            // cannot return None here. Sharded tenants book the chain for
            // the pipeline cycle only, while completion reflects the full
            // fill-path latency (occ_s == svc_s for unsharded tenants, so
            // this degenerates to plain assign()).
            if let Some((_replica, _start, completion)) =
                router.assign_with_occupancy(tenant, time, occ_s[tenant], svc_s[tenant])
            {
                let latency = completion - time;
                latencies[tenant].push(latency);
                // 1 ns slack absorbs the association difference between
                // the admission projection and this exact latency.
                violations[tenant] +=
                    (latency > registry.tenants[tenant].qos.deadline_s + 1e-9) as u64;
                tenant_energy[tenant] += energy_req[tenant];
                tenant_ops[tenant] += ops_req[tenant];
                tenant_transfer_j[tenant] += transfer_req_j[tenant];
                max_completion = max_completion.max(completion);
            }
        }
        // Fire any campaign whose trigger time fell past the last arrival
        // (tiny request counts), so every tenant gets reprogrammed; restore
        // every replica still marked Programming.
        for t in 0..registry.len() {
            if !fired[t] {
                fired[t] = true;
                let reports =
                    Self::fire_campaign(t, &mut fleet, &mut controllers, &mut router, campaign_time[t]);
                total_energy += reports.iter().map(|r| r.energy_j).sum::<f64>();
                let down = reports.iter().map(|r| r.downtime_s()).fold(0.0f64, f64::max);
                max_completion = max_completion.max(campaign_time[t] + down);
                campaigns.extend(reports);
            }
            router.set_health(t, 0, ReplicaHealth::Serving);
        }

        // Assemble the report.
        let mut tenants = Vec::new();
        let mut total_ops = 0.0;
        for t in &registry.tenants {
            let stats = Summary::of(&latencies[t.id]);
            total_energy += tenant_energy[t.id];
            total_ops += tenant_ops[t.id];
            let shards = fleet.tenant_shards(t.id);
            let shard_slices: Vec<usize> = if shards > 1 {
                fleet.replica_chain(t.id, 0).iter().map(|r| r.slice).collect()
            } else {
                Vec::new()
            };
            tenants.push(TenantReport {
                tenant: t.id,
                name: t.name.clone(),
                replicas: t.replicas,
                served: stats.n as u64,
                rejected: admission.rejected[t.id],
                violations: violations[t.id],
                p50_s: stats.p50,
                p99_s: stats.p99,
                mean_s: stats.mean,
                energy_j: tenant_energy[t.id],
                ops: tenant_ops[t.id],
                deadline_s: t.qos.deadline_s,
                shards,
                shard_slices,
                transfer_s: transfer_req_s[t.id],
                transfer_energy_j: tenant_transfer_j[t.id],
            });
        }
        let qos_ok = tenants
            .iter()
            .zip(&registry.tenants)
            .all(|(rep, t)| rep.qos_met(t.qos.max_violation_frac));
        let wear_ok = fleet.wear.iter().all(|w| w.within(&placer.policy));
        let downtime_s = campaigns.iter().map(|c| c.downtime_s()).sum();
        let horizon_s = max_completion.max(1e-12);
        let total_served: u64 = tenants.iter().map(|t| t.served).sum();
        let live = if config.live_serving {
            Some(Self::live_pass(
                &registry,
                config.requests_per_tenant.min(64),
                config.parallelism,
            )?)
        } else {
            None
        };
        Ok(FleetReport {
            slices_used: fleet.slices_used(),
            throughput_rps: total_served as f64 / horizon_s,
            horizon_s,
            total_energy_j: total_energy,
            total_ops,
            campaigns,
            downtime_s,
            wear: fleet.wear,
            wear_ok,
            qos_ok,
            policy: placer.policy,
            tenants,
            live,
        })
    }

    /// Take one tenant's replica 0 — its whole shard chain, for a
    /// shard-parallel tenant — into its drain → program → rewarm campaign
    /// at simulated time `now`, while its siblings keep serving.
    ///
    /// Returns one [`CampaignReport`] per chain segment (a single report
    /// for replica-parallel tenants). Segments live on distinct slices
    /// and reprogram concurrently, so the replica's downtime is the
    /// *slowest* segment's, not the sum; each report carries the shared
    /// drain. On return the replica is left in
    /// [`ReplicaHealth::Programming`] (the drain itself completes within
    /// this call — its duration is the queued work, already accounted in
    /// the reports); the caller restores it to Serving once the clock
    /// passes `now + max downtime`.
    fn fire_campaign(
        tenant: usize,
        fleet: &mut FleetPlacement,
        controllers: &mut [CacheController],
        router: &mut FleetRouter,
        now: f64,
    ) -> Vec<CampaignReport> {
        let chain: Vec<_> =
            fleet.replica_chain(tenant, 0).into_iter().cloned().collect();
        assert!(!chain.is_empty(), "replica 0 placed");
        // The drain phase completes within this synchronous call (its
        // duration is the queued work, reported as drain_s), so the
        // replica goes straight to Programming; the Draining state is for
        // drivers whose drain spans real routing decisions.
        let busy = router.tenants[tenant][0].state.busy_until;
        let drain = (busy - now).max(0.0);
        router.set_health(tenant, 0, ReplicaHealth::Programming);
        let reports: Vec<CampaignReport> = chain
            .iter()
            .map(|placement| {
                CampaignScheduler::run(
                    &mut controllers[placement.slice],
                    placement,
                    &mut fleet.wear[placement.slice],
                    drain,
                )
            })
            .collect();
        // Unavailable until the whole chain completes — both via health
        // (the router skips Programming replicas) and via busy_until
        // (anything assigned right after restoration queues behind the
        // rewarm).
        let downtime = reports.iter().map(|r| r.downtime_s()).fold(0.0f64, f64::max);
        router.tenants[tenant][0].state.busy_until = now + downtime;
        reports
    }

    /// Drive a small request wave through real
    /// [`crate::coordinator::Server`] instances — one per (tenant,
    /// replica) per rewarm segment — each running a hardware-true
    /// PimHw-mode [`crate::coordinator::NativeExecutor`] over a synthetic
    /// network, so the wave serves *from the prepared quantized banks*
    /// on `parallelism` workers — the persistent `pim::parallel` pool,
    /// spawned once per width and reused across every batch and segment
    /// (wall-clock, so the numbers are integration evidence, not part
    /// of the deterministic report).
    ///
    /// The compile-once / execute-many contract runs end to end here:
    /// each serving (tenant, replica) compiles its weight program
    /// **once** (mirroring one-time RRAM programming), then the program
    /// is reused across [`Self::LIVE_SEGMENTS`] campaign-rewarm segments
    /// — the server is torn down and rebuilt between segments, the
    /// `Arc`'d program is not. `rust/tests/fleet.rs` pins
    /// `compilations == Σ replicas < segments` for waves large enough
    /// that every replica serves.
    fn live_pass(
        registry: &ModelRegistry,
        requests_per_tenant: usize,
        parallelism: crate::pim::parallel::Parallelism,
    ) -> Result<LiveSummary> {
        use std::sync::Arc;

        use crate::coordinator::server::{Executor, NativeExecutor, Server, ServerConfig};
        use crate::coordinator::{BatcherConfig, InferenceRequest};
        use crate::nn::resnet::test_params;
        use crate::nn::transformer::{test_tfm_params, TfmConfig};
        use crate::nn::{ForwardMode, ResNet, Transformer};
        use crate::pim::attn::CompiledTransformer;
        use crate::pim::program::CompiledNet;

        use super::registry::ModelFamily;

        /// The compiled program a live replica serves — either workload
        /// family, behind the same generic [`NativeExecutor`].
        #[derive(Clone)]
        enum LiveProgram {
            Cnn(Arc<CompiledNet>),
            Tfm(Arc<CompiledTransformer>),
        }

        const DIMS: (usize, usize, usize) = (16, 16, 3);
        let mut summary =
            LiveSummary { requests: 0, responses: 0, batches: 0, compilations: 0, segments: 0 };
        for tenant in &registry.tenants {
            let tenant_seed = tenant.id as u64;
            // Per-tenant payload geometry: CNN tenants submit 16×16×3
            // frames; transformer tenants submit seq_len × d_model token
            // sequences framed as (seq_len, d_model, 1).
            let dims = match tenant.family {
                ModelFamily::Transformer => (16usize, tenant.width, 1usize),
                _ => DIMS,
            };
            let elems = dims.0 * dims.1 * dims.2;
            let wave = requests_per_tenant;
            let cells = tenant.replicas * Self::LIVE_SEGMENTS;
            let mut img_rng = Pcg64::new(0xA11CE, tenant_seed);
            let mut next_id = (tenant.id * wave) as u64;
            let mut cell = 0usize;
            for _replica in 0..tenant.replicas {
                // This replica's request share per rewarm segment,
                // decided up front: a replica with nothing to serve
                // neither compiles nor counts segments (tiny waves).
                let shares: Vec<usize> = (0..Self::LIVE_SEGMENTS)
                    .map(|_| {
                        let s = wave / cells + usize::from(cell < wave % cells);
                        cell += 1;
                        s
                    })
                    .collect();
                if shares.iter().sum::<usize>() == 0 {
                    continue;
                }
                // Compile once per serving (tenant, replica) — the
                // software mirror of programming this replica's RRAM
                // banks. Both families compile to prepared banks; the
                // transformer's dynamic attention matmuls stay digital
                // and need no preparation.
                let program = match tenant.family {
                    ModelFamily::Transformer => {
                        let cfg = TfmConfig {
                            d_model: tenant.width,
                            n_heads: (tenant.width / 16).max(1),
                            d_ff: 2 * tenant.width,
                            ..TfmConfig::tiny()
                        };
                        let t = Transformer::new(test_tfm_params(cfg, 1 + tenant_seed), cfg)
                            .with_parallelism(parallelism);
                        LiveProgram::Tfm(Arc::new(t.compile()?))
                    }
                    _ => LiveProgram::Cnn(Arc::new(
                        ResNet::new(test_params(8, 10, 1 + tenant_seed))
                            .with_parallelism(parallelism)
                            .compile()?,
                    )),
                };
                summary.compilations += 1;
                for &n_req in &shares {
                    if n_req == 0 {
                        // An empty segment builds no server and counts
                        // as no rewarm.
                        continue;
                    }
                    summary.segments += 1;
                    let seg_program = program.clone();
                    // PimHw: every batch is served from the prepared
                    // banks (NativeExecutor debug-asserts the loop stays
                    // prepare-free).
                    let server = Server::start(
                        Box::new(move || {
                            Ok(match seg_program {
                                LiveProgram::Cnn(p) => Box::new(NativeExecutor::from_program(
                                    p,
                                    ForwardMode::PimHw,
                                    dims,
                                    1,
                                ))
                                    as Box<dyn Executor>,
                                LiveProgram::Tfm(p) => Box::new(NativeExecutor::from_program(
                                    p,
                                    ForwardMode::PimHw,
                                    dims,
                                    1,
                                ))
                                    as Box<dyn Executor>,
                            })
                        }),
                        None,
                        ServerConfig {
                            // Continuous batching end-to-end: the live pass
                            // exercises the merged stepped-execution path
                            // (per-group sub-batches, prepare-free steady
                            // state) rather than drain batching.
                            batcher: BatcherConfig::continuous(
                                8,
                                std::time::Duration::from_millis(1),
                            ),
                        },
                    );
                    for _ in 0..n_req {
                        let image: Vec<f32> =
                            (0..elems).map(|_| img_rng.f64() as f32).collect();
                        server.submit(InferenceRequest::new(next_id, image));
                        next_id += 1;
                    }
                    let mut got = 0u64;
                    for _ in 0..n_req {
                        match server
                            .responses
                            .recv_timeout(std::time::Duration::from_secs(30))
                        {
                            Ok(_) => got += 1,
                            Err(_) => break,
                        }
                    }
                    let metrics = server.shutdown();
                    summary.requests += n_req as u64;
                    summary.responses += got;
                    summary.batches += metrics.batches;
                }
            }
        }
        Ok(summary)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_config() -> FleetSimConfig {
        FleetSimConfig { requests_per_tenant: 120, ..FleetSimConfig::default() }
    }

    #[test]
    fn sim_serves_all_tenants() {
        let report = FleetSim::run(&quick_config()).unwrap();
        assert_eq!(
            report.tenants.len(),
            6,
            "3 synthetic + the wide tenant + 2 transformer tenants"
        );
        assert!(report.slices_used >= 8);
        for t in &report.tenants {
            assert!(t.served > 0, "tenant {} served nothing", t.tenant);
            assert!(t.p99_s >= t.p50_s);
            assert!(t.energy_j > 0.0);
        }
        assert!(report.throughput_rps > 0.0);
    }

    #[test]
    fn transformer_tenants_serve_replica_parallel_with_attribution() {
        let report = FleetSim::run(&quick_config()).unwrap();
        let tfm: Vec<_> =
            report.tenants.iter().filter(|t| t.name.starts_with("tfm-")).collect();
        assert_eq!(tfm.len(), 2, "both standard transformer tenants must run");
        for t in &tfm {
            assert!(t.served > 0, "{} served nothing", t.name);
            assert_eq!(t.shards, 1, "{} fits one slice — replica-parallel", t.name);
            assert!(t.p50_s > 0.0 && t.p99_s >= t.p50_s);
            assert!(t.energy_j > 0.0, "{} needs per-tenant energy attribution", t.name);
            assert!(t.ops > 0.0);
        }
        // The wider geometry costs more per request.
        let tiny = tfm.iter().find(|t| t.name == "tfm-tiny-d64").unwrap();
        let base = tfm.iter().find(|t| t.name == "tfm-base-d128").unwrap();
        assert!(base.energy_j / base.served.max(1) as f64 > tiny.energy_j / tiny.served.max(1) as f64);
    }

    #[test]
    fn no_tfm_flag_restores_the_cnn_only_fleet() {
        let config = FleetSimConfig { transformer_tenants: false, ..quick_config() };
        let report = FleetSim::run(&config).unwrap();
        assert_eq!(report.tenants.len(), 4);
        assert!(report.tenants.iter().all(|t| !t.name.starts_with("tfm-")));
    }

    #[test]
    fn wide_tenant_is_sharded_with_transfer_attribution() {
        let report = FleetSim::run(&quick_config()).unwrap();
        let wide = report.tenants.iter().find(|t| t.name == "resnet18-w24").unwrap();
        assert!(wide.shards >= 2, "over-capacity tenant must serve sharded");
        assert!(wide.served > 0, "the sharded chain must actually serve");
        assert_eq!(wide.shard_slices.len(), wide.shards);
        let distinct: std::collections::HashSet<_> = wide.shard_slices.iter().collect();
        assert_eq!(distinct.len(), wide.shards, "chain must spread across slices");
        assert!(wide.transfer_s > 0.0, "per-hop transfer latency must be attributed");
        assert!(wide.transfer_energy_j > 0.0);
        assert!(
            wide.transfer_energy_j < wide.energy_j,
            "transfer is a breakout of total energy, not an addition"
        );
        // Every replica-parallel tenant reports no transfer.
        for t in report.tenants.iter().filter(|t| t.shards == 1) {
            assert_eq!(t.transfer_s, 0.0);
            assert_eq!(t.transfer_energy_j, 0.0);
            assert!(t.shard_slices.is_empty());
        }
        let text = report.render();
        assert!(text.contains("shard chain"), "render must show the chain:\n{text}");
    }

    #[test]
    fn no_wide_flag_restores_the_replica_only_fleet() {
        let config = FleetSimConfig { wide_tenant: false, ..quick_config() };
        let report = FleetSim::run(&config).unwrap();
        assert_eq!(report.tenants.len(), 5, "3 synthetic + 2 transformers");
        assert!(report.tenants.iter().all(|t| t.shards == 1));
        assert_eq!(report.campaigns.len(), 5);
        assert!(!report.render().contains("shard chain"));
    }

    #[test]
    fn sim_runs_campaigns_with_downtime() {
        let report = FleetSim::run(&quick_config()).unwrap();
        let wide_shards =
            report.tenants.iter().find(|t| t.name == "resnet18-w24").unwrap().shards;
        assert_eq!(
            report.campaigns.len(),
            5 + wide_shards,
            "one campaign per replica-0 segment (3 CNN + 2 tfm + wide chain)"
        );
        assert!(report.downtime_s > 0.0);
        for c in &report.campaigns {
            assert!(c.program_s > 0.0);
            assert_eq!(c.replica, 0);
        }
        // The warmed caches make the rewarm phase real: campaigns displace
        // resident lines and pay to reload them.
        assert!(
            report.campaigns.iter().all(|c| c.lines_displaced > 0 && c.rewarm_s > 0.0),
            "campaigns must displace warmed lines: {:?}",
            report.campaigns.iter().map(|c| c.lines_displaced).collect::<Vec<_>>()
        );
        // Reprogramming bumped wear past the initial programming.
        assert!(report.wear.iter().map(|w| w.max_cycles()).fold(0.0, f64::max) >= 2.0);
        assert!(report.wear_ok);
    }

    #[test]
    fn sim_report_renders_and_serializes() {
        let report = FleetSim::run(&quick_config()).unwrap();
        let text = report.render();
        assert!(text.contains("fleet: 6 tenants"));
        assert!(text.contains(&format!("campaigns: {}", report.campaigns.len())));
        let json = report.to_json();
        assert!(json.get("throughput_rps").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(
            json.get("campaigns").unwrap().as_f64(),
            Some(report.campaigns.len() as f64)
        );
        // Shard/transfer attribution round-trips through JSON.
        let tenants = match json.get("tenants").unwrap() {
            Json::Arr(a) => a,
            other => panic!("tenants must serialize as an array: {other:?}"),
        };
        let max_shards = tenants
            .iter()
            .filter_map(|t| t.get("shards").and_then(|s| s.as_f64()))
            .fold(0.0f64, f64::max);
        assert!(max_shards >= 2.0, "the wide tenant's shard count must serialize");
    }
}
