//! Model registry: the tenants a fleet serves.
//!
//! A *tenant* is a model plus its traffic contract — topology/width (which
//! fixes the sub-array footprint via [`crate::mapping::layout`]), the
//! [`crate::runtime::ModelVariant`] it executes as, how many replicas it
//! wants, the offered load, and a QoS deadline the admission controller
//! and the fleet report enforce.

use crate::coordinator::BankScheduler;
use crate::mapping::conv_mapper::ConvShape;
use crate::runtime::ModelVariant;

/// Quality-of-service contract for one tenant.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QosSpec {
    /// Deadline on simulated end-to-end latency (s).
    pub deadline_s: f64,
    /// Maximum tolerated fraction of served requests past the deadline.
    pub max_violation_frac: f64,
}

impl Default for QosSpec {
    fn default() -> Self {
        QosSpec { deadline_s: 0.05, max_violation_frac: 0.01 }
    }
}

/// Model topology family a tenant deploys.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelFamily {
    /// The full ResNet-18 topology (≈314 sub-array slots at width ≤ 16 —
    /// essentially a whole slice).
    Resnet18,
    /// A compact 6-layer CNN (≈92 slots) so several tenants can share one
    /// slice — the packing case the wear-leveling placer exists for.
    Cnn6,
    /// A small quantized transformer encoder
    /// ([`crate::nn::transformer::TfmConfig`]-shaped, 2 blocks): the
    /// weight-stationary matmuls (QKV, output projection, FFN, head)
    /// occupy banks via
    /// [`BankScheduler::transformer_layers`]; the dynamic attention
    /// matmuls are digital and occupy nothing. `width` is `d_model`.
    Transformer,
}

/// One tenant: a model plus its traffic contract.
#[derive(Clone, Debug)]
pub struct TenantSpec {
    /// Tenant id (index in the registry).
    pub id: usize,
    /// Human-readable name for reports.
    pub name: String,
    /// Topology family.
    pub family: ModelFamily,
    /// Trunk width. For CNN families this is the channel-count knob
    /// (keep ≤ 16 so channels stay within one 128-row tile for the live
    /// executor — wider tenants are legal for the analytic/placement
    /// path and overflow a slice, which is exactly what forces the
    /// shard-parallel mode in [`crate::fleet::shard`]). For
    /// [`ModelFamily::Transformer`] it is `d_model` (64 or 128 for the
    /// standard tenants).
    pub width: usize,
    /// Which runtime variant the tenant's replicas execute.
    pub variant: ModelVariant,
    /// Replicas requested.
    pub replicas: usize,
    /// Offered load per replica as a fraction of one replica's service
    /// capacity (the simulator converts this into an arrival rate once the
    /// model's service time is known).
    pub utilization: f64,
    /// QoS contract.
    pub qos: QosSpec,
}

impl TenantSpec {
    /// The tenant's layer stack, in execution order.
    pub fn layers(&self) -> Vec<ConvShape> {
        match self.family {
            ModelFamily::Resnet18 => BankScheduler::resnet18_layers(self.width),
            ModelFamily::Cnn6 => {
                let w = self.width;
                vec![
                    ConvShape { k: 3, d: 3, n: w, w: 16, stride: 1 },
                    ConvShape { k: 3, d: w, n: w, w: 16, stride: 2 },
                    ConvShape { k: 3, d: w, n: 2 * w, w: 8, stride: 1 },
                    ConvShape { k: 3, d: 2 * w, n: 2 * w, w: 8, stride: 2 },
                    ConvShape { k: 3, d: 2 * w, n: 4 * w, w: 4, stride: 1 },
                    ConvShape { k: 1, d: 4 * w, n: 10, w: 1, stride: 1 }, // FC
                ]
            }
            ModelFamily::Transformer => BankScheduler::transformer_layers(self.width, 2),
        }
    }
}

/// The registry of tenants in the fleet.
#[derive(Clone, Debug, Default)]
pub struct ModelRegistry {
    /// Registered tenants, indexed by [`TenantSpec::id`].
    pub tenants: Vec<TenantSpec>,
}

impl ModelRegistry {
    /// Empty registry.
    pub fn new() -> ModelRegistry {
        ModelRegistry { tenants: Vec::new() }
    }

    /// Register a tenant; its id is assigned and returned.
    pub fn register(&mut self, mut tenant: TenantSpec) -> usize {
        let id = self.tenants.len();
        tenant.id = id;
        self.tenants.push(tenant);
        id
    }

    /// A synthetic multi-tenant fleet with distinct sizes, variants, and
    /// QoS contracts: tenant 0 is a slice-filling ResNet-18, the rest are
    /// compact CNNs of varying width that pack several-per-slice.
    pub fn synthetic(n: usize) -> ModelRegistry {
        let mut reg = ModelRegistry::new();
        let variants = [ModelVariant::Pim, ModelVariant::PimNoise, ModelVariant::Baseline];
        for i in 0..n {
            let (family, width, name) = if i == 0 {
                (ModelFamily::Resnet18, 16, "resnet18-w16".to_string())
            } else {
                let w = [8usize, 12, 16][(i - 1) % 3];
                (ModelFamily::Cnn6, w, format!("cnn6-w{w}"))
            };
            reg.register(TenantSpec {
                id: 0, // assigned by register()
                name,
                family,
                width,
                variant: variants[i % variants.len()],
                replicas: 2,
                utilization: 0.4 + 0.1 * (i % 3) as f64,
                qos: QosSpec {
                    deadline_s: if i == 0 { 0.05 } else { 0.02 },
                    max_violation_frac: 0.01,
                },
            });
        }
        reg
    }

    /// The over-capacity wide-ResNet tenant: width 24 needs ≈498
    /// sub-array slots against a default slice's 320, so a whole replica
    /// *cannot* be placed on any single slice — the placer must take the
    /// shard-parallel path ([`crate::fleet::shard`]) and split its layer
    /// stack across slices. One replica by default (the chain already
    /// spans multiple slices) with moderate offered load.
    pub fn wide_tenant(replicas: usize) -> TenantSpec {
        TenantSpec {
            id: 0, // assigned by register()
            name: "resnet18-w24".to_string(),
            family: ModelFamily::Resnet18,
            width: 24,
            variant: ModelVariant::Pim,
            replicas,
            utilization: 0.4,
            qos: QosSpec { deadline_s: 0.05, max_violation_frac: 0.01 },
        }
    }

    /// [`Self::synthetic`] plus the over-capacity [`Self::wide_tenant`]
    /// appended — the standard mixed fleet for shard-mode scenarios.
    pub fn synthetic_with_wide(n: usize) -> ModelRegistry {
        let mut reg = Self::synthetic(n);
        reg.register(Self::wide_tenant(1));
        reg
    }

    /// A transformer tenant at `d_model` ∈ {64, 128} — the standard
    /// second-family tenants (`tfm-tiny-d64`, `tfm-base-d128`). Both
    /// fit comfortably on one slice (their bank-resident layers are 1×1
    /// matmuls), so they place replica-parallel and pack alongside the
    /// compact CNNs.
    pub fn tfm_tenant(d_model: usize, replicas: usize) -> TenantSpec {
        let name = match d_model {
            64 => "tfm-tiny-d64".to_string(),
            128 => "tfm-base-d128".to_string(),
            d => format!("tfm-d{d}"),
        };
        TenantSpec {
            id: 0, // assigned by register()
            name,
            family: ModelFamily::Transformer,
            width: d_model,
            variant: ModelVariant::Pim,
            replicas,
            utilization: 0.35,
            qos: QosSpec { deadline_s: 0.03, max_violation_frac: 0.01 },
        }
    }

    /// Append the two standard transformer tenants, making this a mixed
    /// CNN+transformer fleet (the default `fleet-sim` scenario;
    /// `--no-tfm` skips this).
    pub fn with_transformers(mut self) -> ModelRegistry {
        self.register(Self::tfm_tenant(64, 2));
        self.register(Self::tfm_tenant(128, 1));
        self
    }

    /// Number of tenants.
    pub fn len(&self) -> usize {
        self.tenants.len()
    }

    /// Is the registry empty?
    pub fn is_empty(&self) -> bool {
        self.tenants.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_tenants_are_distinct() {
        let reg = ModelRegistry::synthetic(3);
        assert_eq!(reg.len(), 3);
        assert_eq!(reg.tenants[0].family, ModelFamily::Resnet18);
        assert_eq!(reg.tenants[1].family, ModelFamily::Cnn6);
        assert_ne!(reg.tenants[1].width, reg.tenants[2].width);
        assert_ne!(reg.tenants[0].variant, reg.tenants[1].variant);
        for (i, t) in reg.tenants.iter().enumerate() {
            assert_eq!(t.id, i);
            assert!(t.replicas >= 2);
            assert!(t.utilization < 0.75, "offered load must leave headroom");
        }
    }

    #[test]
    fn cnn6_is_much_smaller_than_resnet18() {
        use crate::mapping::layout::NetworkLayout;
        let reg = ModelRegistry::synthetic(2);
        let big = NetworkLayout::place(&reg.tenants[0].layers(), 80, 4).unwrap();
        let small = NetworkLayout::place(&reg.tenants[1].layers(), 80, 4).unwrap();
        assert!(small.slots_used * 3 <= big.slots_used, "{} vs {}", small.slots_used, big.slots_used);
        assert!(small.slots_used * 3 <= 320, "three compact tenants must share a slice");
    }

    #[test]
    fn wide_tenant_overflows_a_default_slice() {
        use crate::mapping::layout::NetworkLayout;
        let wide = ModelRegistry::wide_tenant(1);
        assert!(
            NetworkLayout::place(&wide.layers(), 80, 4).is_none(),
            "the wide tenant must not fit one slice — it exists to force sharding"
        );
        let reg = ModelRegistry::synthetic_with_wide(3);
        assert_eq!(reg.len(), 4);
        assert_eq!(reg.tenants[3].name, "resnet18-w24");
        assert_eq!(reg.tenants[3].id, 3);
    }

    #[test]
    fn transformer_tenants_round_trip_and_fit_one_slice() {
        use crate::mapping::layout::NetworkLayout;
        let reg = ModelRegistry::synthetic_with_wide(3).with_transformers();
        assert_eq!(reg.len(), 6, "3 synthetic + wide + 2 transformers");
        let tiny = &reg.tenants[4];
        let base = &reg.tenants[5];
        assert_eq!(tiny.name, "tfm-tiny-d64");
        assert_eq!(base.name, "tfm-base-d128");
        assert_eq!((tiny.id, base.id), (4, 5));
        assert_eq!(tiny.family, ModelFamily::Transformer);
        // 4 bank-resident layers per block × 2 blocks + head.
        assert_eq!(tiny.layers().len(), 9);
        // Unlike the wide CNN tenant, both transformer geometries place
        // replica-parallel: a whole replica fits one slice.
        for t in [tiny, base] {
            assert!(
                NetworkLayout::place(&t.layers(), 80, 4).is_some(),
                "{} must fit one slice",
                t.name
            );
        }
        // The base geometry is strictly larger.
        let small = NetworkLayout::place(&tiny.layers(), 80, 4).unwrap();
        let big = NetworkLayout::place(&base.layers(), 80, 4).unwrap();
        assert!(big.slots_used > small.slots_used);
    }

    #[test]
    fn register_assigns_sequential_ids() {
        let mut reg = ModelRegistry::new();
        let t = TenantSpec {
            id: 99,
            name: "x".into(),
            family: ModelFamily::Cnn6,
            width: 8,
            variant: ModelVariant::Pim,
            replicas: 1,
            utilization: 0.5,
            qos: QosSpec::default(),
        };
        assert_eq!(reg.register(t.clone()), 0);
        assert_eq!(reg.register(t), 1);
        assert_eq!(reg.tenants[1].id, 1);
    }
}
