//! Endurance-aware placement of tenant replicas onto a fleet of slices.
//!
//! Each replica's weight tiles are packed contiguously onto one slice via
//! [`crate::mapping::layout::NetworkLayout::place_from`]. The placer
//! tracks per-bank RRAM write-cycle wear ([`BankWear`]) and refuses any
//! placement whose planned reprogramming campaigns would push a bank's
//! resistance window below the [`EndurancePolicy`] criterion — endurance
//! as a first-class scheduling input, not an afterthought (Inci et al.).
//!
//! Since the `fleet::shard` subsystem, a replica is no longer forced to
//! be whole: per tenant, [`crate::fleet::shard::choose_mode`] decides
//! replica-parallel vs shard-parallel, and in shard mode each replica
//! becomes a *chain* of [`ReplicaPlacement`]s (one per shard segment,
//! preferably on distinct slices so the chain actually pipelines), each
//! with its own wear accounting.

use crate::cache::addr::Geometry;
use crate::device::reliability::EnduranceModel;
use crate::mapping::layout::NetworkLayout;
use crate::{Error, Result};

use super::registry::ModelRegistry;
use super::shard::{choose_mode, PlacementMode, ShardPlan, ShardSegment};

/// Per-bank RRAM write-cycle counters for one slice.
#[derive(Clone, Debug)]
pub struct BankWear {
    /// Accumulated SET/RESET campaign cycles per bank.
    pub cycles: Vec<f64>,
}

impl BankWear {
    /// Fresh (unworn) wear state for `banks` banks.
    pub fn new(banks: usize) -> BankWear {
        BankWear { cycles: vec![0.0; banks] }
    }

    /// Record one programming campaign touching `bank`.
    pub fn record_program(&mut self, bank: usize) {
        self.cycles[bank] += 1.0;
    }

    /// Most-worn bank's cycle count.
    pub fn max_cycles(&self) -> f64 {
        self.cycles.iter().cloned().fold(0.0, f64::max)
    }

    /// Worst (smallest) remaining resistance-window fraction across banks.
    pub fn min_window_fraction(&self, model: &EnduranceModel) -> f64 {
        self.cycles
            .iter()
            .map(|&c| model.window_fraction(c))
            .fold(1.0, f64::min)
    }

    /// Are all banks still inside the policy's window criterion?
    pub fn within(&self, policy: &EndurancePolicy) -> bool {
        self.min_window_fraction(&policy.model) >= policy.min_window
    }
}

/// Endurance policy the placer enforces.
#[derive(Clone, Copy, Debug)]
pub struct EndurancePolicy {
    /// Device endurance model.
    pub model: EnduranceModel,
    /// Refuse placements whose projected window falls below this fraction.
    pub min_window: f64,
    /// Reprogramming campaigns each placement must have headroom for over
    /// the deployment lifetime (e.g. daily retraining for 10 years ≈ 3653).
    pub planned_campaigns: f64,
}

impl Default for EndurancePolicy {
    fn default() -> Self {
        EndurancePolicy {
            model: EnduranceModel::default(),
            min_window: 0.8,
            planned_campaigns: 10.0 * 365.25,
        }
    }
}

/// One placed replica segment: a tenant's tile layout on one slice. For
/// replica-parallel tenants this is the whole replica (`n_shards == 1`);
/// for shard-parallel tenants each replica is a chain of these, one per
/// shard segment.
#[derive(Clone, Debug)]
pub struct ReplicaPlacement {
    /// Owning tenant id.
    pub tenant: usize,
    /// Replica index within the tenant.
    pub replica: usize,
    /// Position in the replica's shard chain (0 for unsharded).
    pub shard: usize,
    /// Total shards in the chain (1 for unsharded).
    pub n_shards: usize,
    /// Half-open range into the tenant's layer list this segment hosts.
    pub layer_range: (usize, usize),
    /// Slice hosting this segment.
    pub slice: usize,
    /// First linear slot of the placement on that slice.
    pub start_slot: usize,
    /// The tile layout (slots are slice-local).
    pub layout: NetworkLayout,
}

impl ReplicaPlacement {
    /// Banks this replica's tiles occupy (sorted, deduplicated).
    pub fn banks(&self) -> Vec<usize> {
        let mut banks: Vec<usize> = self
            .layout
            .placements
            .iter()
            .flat_map(|p| [p.pos_slot.0, p.neg_slot.0])
            .collect();
        banks.sort_unstable();
        banks.dedup();
        banks
    }
}

/// The fleet-wide placement produced by [`EndurancePlacer::place`].
#[derive(Clone, Debug)]
pub struct FleetPlacement {
    /// Every placed replica segment.
    pub replicas: Vec<ReplicaPlacement>,
    /// Per-slice bank wear (updated by campaigns as they run).
    pub wear: Vec<BankWear>,
    /// Slots consumed per slice.
    pub slots_used: Vec<usize>,
    /// Per tenant id: the shard plan the placer committed to, `None` for
    /// replica-parallel tenants. The fleet sim derives per-shard stage
    /// costs and transfer links from this, so the cost model and the
    /// placement can never disagree about where the cuts fall.
    pub shard_plans: Vec<Option<ShardPlan>>,
}

impl FleetPlacement {
    /// Number of distinct slices hosting at least one replica.
    pub fn slices_used(&self) -> usize {
        self.slots_used.iter().filter(|&&s| s > 0).count()
    }

    /// The placements belonging to one tenant.
    pub fn tenant_replicas(&self, tenant: usize) -> Vec<&ReplicaPlacement> {
        self.replicas.iter().filter(|r| r.tenant == tenant).collect()
    }

    /// One replica's shard chain, in shard order (a single element for
    /// replica-parallel tenants).
    pub fn replica_chain(&self, tenant: usize, replica: usize) -> Vec<&ReplicaPlacement> {
        let mut chain: Vec<&ReplicaPlacement> = self
            .replicas
            .iter()
            .filter(|r| r.tenant == tenant && r.replica == replica)
            .collect();
        chain.sort_by_key(|r| r.shard);
        chain
    }

    /// Shards per replica for one tenant (1 when replica-parallel).
    pub fn tenant_shards(&self, tenant: usize) -> usize {
        self.shard_plans
            .get(tenant)
            .and_then(|p| p.as_ref().map(ShardPlan::shards))
            .unwrap_or(1)
    }
}

/// The endurance-aware placer.
pub struct EndurancePlacer {
    /// Slice geometry (identical across the fleet).
    pub geom: Geometry,
    /// Slices available.
    pub n_slices: usize,
    /// Endurance policy.
    pub policy: EndurancePolicy,
    /// Longest shard chain [`choose_mode`] may plan per replica.
    pub max_shards: usize,
}

impl EndurancePlacer {
    /// Placer over `n_slices` identical slices.
    pub fn new(geom: Geometry, n_slices: usize) -> EndurancePlacer {
        EndurancePlacer {
            geom,
            n_slices,
            policy: EndurancePolicy::default(),
            max_shards: n_slices.clamp(1, 4),
        }
    }

    /// Place every tenant's replicas across a fresh (unworn) fleet.
    pub fn place(&self, registry: &ModelRegistry) -> Result<FleetPlacement> {
        let fresh =
            (0..self.n_slices).map(|_| BankWear::new(self.geom.banks_per_slice)).collect();
        self.place_with_wear(registry, fresh)
    }

    /// Place every tenant's replicas across the fleet, starting from the
    /// given per-slice wear state (e.g. carried over from a previous
    /// deployment generation).
    ///
    /// Slice choice per replica segment: among *feasible* slices — enough
    /// free slots AND endurance headroom on every bank the placement
    /// would touch — prefer (1) slices not already hosting this tenant
    /// (fault isolation; for a shard chain this also spreads the chain's
    /// segments across distinct slices so the pipeline actually
    /// overlaps), (2) least-worn (wear-leveling), (3) least-occupied,
    /// (4) lowest index — a total order, so placement is deterministic.
    /// Refuses with [`Error::Config`] only when no slice is feasible
    /// (insufficient capacity, or the planned campaigns would exceed a
    /// touched bank's endurance budget everywhere).
    ///
    /// Per tenant, [`choose_mode`] first decides replica-parallel vs
    /// shard-parallel: a tenant whose whole replica fits one slice and
    /// meets its deadline places exactly as before (one segment,
    /// `n_shards == 1`); an over-capacity or deadline-bound tenant is
    /// split per its [`ShardPlan`] and each segment placed like a
    /// mini-replica with its own wear/commitment accounting.
    pub fn place_with_wear(
        &self,
        registry: &ModelRegistry,
        mut wear: Vec<BankWear>,
    ) -> Result<FleetPlacement> {
        assert_eq!(wear.len(), self.n_slices, "one wear state per slice");
        let capacity = self.geom.banks_per_slice * self.geom.subarrays_per_bank;
        let mut slots_used = vec![0usize; self.n_slices];
        // Campaigns already committed to each bank by replicas placed in
        // this round: a bank straddling two replicas (contiguous packing
        // splits banks at slot boundaries) must have headroom for *both*
        // replicas' campaign schedules, not each in isolation.
        let mut committed = vec![vec![0.0f64; self.geom.banks_per_slice]; self.n_slices];
        let mut replicas: Vec<ReplicaPlacement> = Vec::new();
        let mut shard_plans: Vec<Option<ShardPlan>> = Vec::new();
        for tenant in &registry.tenants {
            let layers = tenant.layers();
            let mode = choose_mode(
                &layers,
                &self.geom,
                tenant.qos.deadline_s,
                tenant.utilization,
                self.max_shards,
            )
            .map_err(|e| {
                Error::Config(format!(
                    "tenant {} ({}) does not fit a single slice and cannot be sharded: {e}",
                    tenant.id, tenant.name
                ))
            })?;
            // Uniform view: a replica is a chain of segments (length 1
            // when replica-parallel).
            let segments: Vec<ShardSegment> = match &mode {
                PlacementMode::Replica => {
                    let slots = NetworkLayout::place(
                        &layers,
                        self.geom.banks_per_slice,
                        self.geom.subarrays_per_bank,
                    )
                    .expect("choose_mode returned Replica only for a fitting tenant")
                    .next_slot();
                    vec![ShardSegment {
                        shard: 0,
                        layer_range: (0, layers.len()),
                        filter_range: None,
                        layers: layers.clone(),
                        slots,
                    }]
                }
                PlacementMode::Sharded(plan) => plan.segments.clone(),
            };
            let n_shards = segments.len();
            shard_plans.push(match mode {
                PlacementMode::Sharded(plan) => Some(plan),
                PlacementMode::Replica => None,
            });
            for replica in 0..tenant.replicas {
                for seg in &segments {
                    let need = seg.slots;
                    let hosted: Vec<usize> = replicas
                        .iter()
                        .filter(|r| r.tenant == tenant.id)
                        .map(|r| r.slice)
                        .collect();
                    // Feasibility of one candidate slice: room for `need`
                    // contiguous slots AND endurance headroom on every
                    // bank the placement would touch — the planned
                    // campaign schedule plus this segment's own initial
                    // programming cycle, on top of the bank's wear and
                    // whatever co-placed replicas already committed to a
                    // shared bank. (Placement is contiguous, so the
                    // touched banks are exactly start..start+need.)
                    let spb = self.geom.subarrays_per_bank;
                    let demand = self.policy.planned_campaigns + 1.0;
                    let feasible = |s: usize| -> bool {
                        let start = slots_used[s];
                        if start + need > capacity {
                            return false;
                        }
                        let first_bank = start / spb;
                        let last_bank = (start + need - 1) / spb;
                        (first_bank..=last_bank).all(|bank| {
                            self.policy
                                .model
                                .remaining_campaigns(wear[s].cycles[bank], self.policy.min_window)
                                >= committed[s][bank] + demand
                        })
                    };
                    let slice = (0..self.n_slices)
                        .filter(|&s| feasible(s))
                        .min_by(|&a, &b| {
                            let key = |s: usize| {
                                (
                                    hosted.contains(&s) as usize,
                                    // f64 wear is a sum of 1.0s — total_cmp safe.
                                    wear[s].max_cycles(),
                                    slots_used[s],
                                    s,
                                )
                            };
                            let (ha, wa, ua, ia) = key(a);
                            let (hb, wb, ub, ib) = key(b);
                            ha.cmp(&hb)
                                .then(wa.total_cmp(&wb))
                                .then(ua.cmp(&ub))
                                .then(ia.cmp(&ib))
                        })
                        .ok_or_else(|| {
                            Error::Config(format!(
                                "no slice can host tenant {} replica {replica} shard {}: needs \
                                 {need} free slots with endurance headroom for {:.0} more \
                                 campaigns per bank (campaigns already committed to shared banks \
                                 count against the budget; {} slices, {capacity} slots each)",
                                tenant.id, seg.shard, self.policy.planned_campaigns, self.n_slices
                            ))
                        })?;
                    let layout = NetworkLayout::place_from(
                        &seg.layers,
                        self.geom.banks_per_slice,
                        self.geom.subarrays_per_bank,
                        slots_used[slice],
                    )
                    .ok_or_else(|| {
                        Error::Config("placement overflow despite capacity check".into())
                    })?;
                    let placement = ReplicaPlacement {
                        tenant: tenant.id,
                        replica,
                        shard: seg.shard,
                        n_shards,
                        layer_range: seg.layer_range,
                        slice,
                        start_slot: slots_used[slice],
                        layout,
                    };
                    for bank in placement.banks() {
                        committed[slice][bank] += demand;
                    }
                    slots_used[slice] += placement.layout.slots_used;
                    replicas.push(placement);
                }
            }
        }
        // Wear counters start at the initial programming: one campaign per
        // touched bank per replica segment.
        for r in &replicas {
            for bank in r.banks() {
                wear[r.slice].record_program(bank);
            }
        }
        Ok(FleetPlacement { replicas, wear, slots_used, shard_plans })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::registry::ModelRegistry;

    fn placer(n_slices: usize) -> EndurancePlacer {
        EndurancePlacer::new(Geometry::default(), n_slices)
    }

    #[test]
    fn places_synthetic_fleet_across_slices() {
        let reg = ModelRegistry::synthetic(3);
        let p = placer(4).place(&reg).unwrap();
        assert_eq!(p.replicas.len(), 6, "3 tenants × 2 replicas");
        assert!(p.slices_used() >= 4, "slices used: {}", p.slices_used());
        for t in 0..3 {
            assert_eq!(p.tenant_replicas(t).len(), 2);
        }
    }

    #[test]
    fn same_tenant_replicas_prefer_distinct_slices() {
        let reg = ModelRegistry::synthetic(3);
        let p = placer(4).place(&reg).unwrap();
        for t in 0..3 {
            let slices: Vec<usize> = p.tenant_replicas(t).iter().map(|r| r.slice).collect();
            assert_ne!(slices[0], slices[1], "tenant {t} replicas co-located: {slices:?}");
        }
    }

    #[test]
    fn no_slot_overlap_within_a_slice() {
        let reg = ModelRegistry::synthetic(4);
        let p = placer(5).place(&reg).unwrap();
        for s in 0..5 {
            let mut seen = std::collections::HashSet::new();
            for r in p.replicas.iter().filter(|r| r.slice == s) {
                for tp in &r.layout.placements {
                    assert!(seen.insert(tp.pos_slot), "slice {s} double-books {:?}", tp.pos_slot);
                    assert!(seen.insert(tp.neg_slot), "slice {s} double-books {:?}", tp.neg_slot);
                }
            }
        }
    }

    #[test]
    fn placement_is_deterministic() {
        let reg = ModelRegistry::synthetic(3);
        let a = placer(4).place(&reg).unwrap();
        let b = placer(4).place(&reg).unwrap();
        let key = |p: &FleetPlacement| -> Vec<(usize, usize, usize, usize)> {
            p.replicas.iter().map(|r| (r.tenant, r.replica, r.slice, r.start_slot)).collect()
        };
        assert_eq!(key(&a), key(&b));
    }

    #[test]
    fn refuses_when_capacity_insufficient() {
        let reg = ModelRegistry::synthetic(3);
        assert!(placer(2).place(&reg).is_err(), "6 replicas cannot fit 2 slices");
    }

    #[test]
    fn refuses_when_endurance_budget_exceeded() {
        let reg = ModelRegistry::synthetic(3);
        let mut pl = placer(4);
        // Demand more campaigns than a fresh bank can ever absorb.
        pl.policy.planned_campaigns = pl.policy.model.max_campaigns(pl.policy.min_window) + 1.0;
        let err = pl.place(&reg).unwrap_err();
        assert!(err.to_string().contains("endurance"), "{err}");
    }

    #[test]
    fn shared_bank_commitments_accumulate() {
        // Two co-placed replicas must not each claim the full headroom of
        // a bank they share. With 8 sub-arrays per bank, the 92-slot
        // compact CNN ends mid-bank (92 % 8 = 4), so replica 1 starts in
        // replica 0's last bank. Give each replica headroom for only ~1.5×
        // the planned schedule: alone either fits, together the shared
        // bank must be refused.
        let mut reg = ModelRegistry::synthetic(2);
        reg.tenants.remove(0); // keep only the compact CNN tenant
        reg.tenants[0].id = 0;
        reg.tenants[0].replicas = 2;
        let geom = Geometry { banks_per_slice: 40, subarrays_per_bank: 8, ..Geometry::default() };
        let mut pl = EndurancePlacer::new(geom, 1); // one slice forces co-placement
        assert!(pl.place(&reg).is_ok(), "fits under the default campaign budget");
        let max = pl.policy.model.max_campaigns(pl.policy.min_window);
        pl.policy.planned_campaigns = max / 1.5;
        let err = pl.place(&reg).unwrap_err();
        assert!(err.to_string().contains("committed"), "{err}");
    }

    #[test]
    fn wear_leveling_avoids_worn_slices() {
        // Only the compact tenants (no slice-filling ResNet) so every slice
        // is a candidate; pre-wear slice 0 heavily.
        let mut reg = ModelRegistry::synthetic(4);
        reg.tenants.remove(0);
        for (i, t) in reg.tenants.iter_mut().enumerate() {
            t.id = i;
            t.replicas = 1;
        }
        let pl = placer(4);
        let mut prior: Vec<BankWear> =
            (0..4).map(|_| BankWear::new(pl.geom.banks_per_slice)).collect();
        for c in prior[0].cycles.iter_mut() {
            *c = 1e3;
        }
        let p = pl.place_with_wear(&reg, prior).unwrap();
        assert!(
            p.replicas.iter().all(|r| r.slice != 0),
            "worn slice 0 must be avoided while fresh slices have room: {:?}",
            p.replicas.iter().map(|r| r.slice).collect::<Vec<_>>()
        );
    }

    #[test]
    fn falls_back_to_feasible_slice_instead_of_failing() {
        // Slice 0 looks best by the preference key (lower max wear) but
        // has no endurance headroom anywhere; slice 1 carries one heavily
        // worn bank outside the placement range and fresh banks in it.
        // The placer must skip slice 0, not refuse the fleet.
        let mut reg = ModelRegistry::synthetic(2);
        reg.tenants.remove(0); // keep only the compact CNN tenant
        reg.tenants[0].id = 0;
        reg.tenants[0].replicas = 1;
        let pl = placer(2);
        let max = pl.policy.model.max_campaigns(pl.policy.min_window);
        let mut prior: Vec<BankWear> =
            (0..2).map(|_| BankWear::new(pl.geom.banks_per_slice)).collect();
        for c in prior[0].cycles.iter_mut() {
            *c = max - 1.0;
        }
        prior[1].cycles[79] = max + 1.0;
        let p = pl.place_with_wear(&reg, prior).unwrap();
        assert_eq!(p.replicas[0].slice, 1, "infeasible slice 0 skipped, not fatal");
    }

    #[test]
    fn wide_tenant_places_as_a_shard_chain() {
        let reg = ModelRegistry::synthetic_with_wide(3);
        let p = placer(8).place(&reg).unwrap();
        // Synthetic tenants stay replica-parallel…
        for t in 0..3 {
            assert_eq!(p.tenant_shards(t), 1);
            assert!(p.shard_plans[t].is_none());
            assert!(p.tenant_replicas(t).iter().all(|r| r.n_shards == 1));
        }
        // …while the over-capacity tenant becomes a chain of 2+ segments
        // on distinct slices, covering the layer list contiguously.
        let wide = 3;
        let shards = p.tenant_shards(wide);
        assert!(shards >= 2, "wide tenant must shard");
        let chain = p.replica_chain(wide, 0);
        assert_eq!(chain.len(), shards);
        let mut slices = std::collections::HashSet::new();
        let mut next_layer = 0;
        for (k, seg) in chain.iter().enumerate() {
            assert_eq!(seg.shard, k);
            assert_eq!(seg.n_shards, shards);
            assert!(slices.insert(seg.slice), "chain segments must spread across slices");
            assert_eq!(seg.layer_range.0, next_layer);
            next_layer = seg.layer_range.1.max(next_layer);
        }
        assert_eq!(next_layer, reg.tenants[wide].layers().len());
    }

    #[test]
    fn initial_programming_recorded_as_wear() {
        let reg = ModelRegistry::synthetic(3);
        let p = placer(4).place(&reg).unwrap();
        assert!(p.wear.iter().any(|w| w.max_cycles() >= 1.0));
        for w in &p.wear {
            assert!(w.within(&EndurancePolicy::default()));
        }
    }
}
