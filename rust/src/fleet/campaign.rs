//! Campaign scheduler: interleaves destructive weight-programming
//! campaigns with live traffic.
//!
//! Programming the RRAM layer is destructive to the SRAM latches
//! (§III-A), so a replica must be taken through **drain → program →
//! rewarm** before it can serve again:
//!
//! 1. *drain* — stop routing to the replica and wait for its in-flight
//!    work (a driver with an asynchronous drain window marks it
//!    [`super::router::ReplicaHealth::Draining`]; the synchronous fleet
//!    simulator accounts the wait as `drain_s` and goes straight to
//!    [`super::router::ReplicaHealth::Programming`]);
//! 2. *program* — run [`crate::cache::CacheController::program_campaign`]
//!    for every tile slot, metered through [`crate::cell::timing`];
//! 3. *rewarm* — reload the cache lines the programming displaced
//!    ([`crate::cache::CacheController::rewarm_campaign`]).
//!
//! The sum of the three phases is the replica's campaign downtime, which
//! the fleet report pins alongside QoS and wear.

use crate::cache::controller::CacheController;
use crate::consts::{ARRAY_ROWS, ARRAY_WORDS};

use super::placer::{BankWear, ReplicaPlacement};

/// Outcome of one replica's programming campaign.
#[derive(Clone, Debug)]
pub struct CampaignReport {
    /// Tenant owning the reprogrammed replica.
    pub tenant: usize,
    /// Replica index within the tenant.
    pub replica: usize,
    /// Slice the replica lives on.
    pub slice: usize,
    /// Time spent waiting for in-flight work to drain (s).
    pub drain_s: f64,
    /// Programming latency across all tile slots (s).
    pub program_s: f64,
    /// Cache-rewarm latency (s).
    pub rewarm_s: f64,
    /// Cache lines displaced by the destructive programming.
    pub lines_displaced: u64,
    /// Energy of programming + rewarm (J).
    pub energy_j: f64,
}

impl CampaignReport {
    /// Total replica downtime: drain + program + rewarm (s).
    pub fn downtime_s(&self) -> f64 {
        self.drain_s + self.program_s + self.rewarm_s
    }
}

/// Stateless executor for drain → program → rewarm campaigns.
pub struct CampaignScheduler;

impl CampaignScheduler {
    /// Reprogram one replica's weights in place on its slice.
    ///
    /// `drain_s` is the simulated time the caller spent draining in-flight
    /// work before calling. Every touched bank's wear counter is bumped by
    /// one campaign cycle.
    pub fn run(
        controller: &mut CacheController,
        placement: &ReplicaPlacement,
        wear: &mut BankWear,
        drain_s: f64,
    ) -> CampaignReport {
        let mut program_s = 0.0;
        let mut energy_j = 0.0;
        let mut lines_displaced = 0u64;
        let mut snapshots = Vec::new();
        for tile in &placement.layout.placements {
            for (bank, sa) in [tile.pos_slot, tile.neg_slot] {
                let saved = controller.resident_snapshot(bank, sa);
                let stats = controller.program_campaign(
                    bank,
                    sa,
                    vec![0u8; ARRAY_ROWS * ARRAY_WORDS],
                );
                program_s += stats.latency;
                energy_j += stats.energy;
                lines_displaced += stats.lines_moved;
                snapshots.push((bank, sa, saved));
            }
        }
        for bank in placement.banks() {
            wear.record_program(bank);
        }
        // Reload everything the programming displaced, so the cache model
        // is warm again and a later campaign pays the same displacement.
        let mut rewarm_s = 0.0;
        for (bank, sa, saved) in &snapshots {
            let rewarm = controller.rewarm_campaign(*bank, *sa, saved);
            rewarm_s += rewarm.latency;
            energy_j += rewarm.energy;
        }
        CampaignReport {
            tenant: placement.tenant,
            replica: placement.replica,
            slice: placement.slice,
            drain_s,
            program_s,
            rewarm_s,
            lines_displaced,
            energy_j,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::addr::Geometry;
    use crate::cache::controller::PimIntegration;
    use crate::fleet::placer::EndurancePlacer;
    use crate::fleet::registry::ModelRegistry;

    fn one_placement() -> (CacheController, ReplicaPlacement, BankWear) {
        let reg = ModelRegistry::synthetic(2);
        let placer = EndurancePlacer::new(Geometry::default(), 4);
        let fleet = placer.place(&reg).unwrap();
        // Take the compact tenant's first replica.
        let placement = fleet.tenant_replicas(1)[0].clone();
        let controller = CacheController::new(Geometry::default(), PimIntegration::Retained);
        let wear = BankWear::new(Geometry::default().banks_per_slice);
        (controller, placement, wear)
    }

    #[test]
    fn campaign_meters_program_and_rewarm() {
        let (mut c, placement, mut wear) = one_placement();
        let report = CampaignScheduler::run(&mut c, &placement, &mut wear, 1e-3);
        assert!(report.program_s > 0.0);
        assert!(report.energy_j > 0.0);
        assert!(
            (report.downtime_s() - (1e-3 + report.program_s + report.rewarm_s)).abs() < 1e-15
        );
        assert_eq!(report.tenant, 1);
    }

    #[test]
    fn campaign_bumps_wear_on_touched_banks_only() {
        let (mut c, placement, mut wear) = one_placement();
        CampaignScheduler::run(&mut c, &placement, &mut wear, 0.0);
        let touched = placement.banks();
        for (bank, cycles) in wear.cycles.iter().enumerate() {
            if touched.contains(&bank) {
                assert_eq!(*cycles, 1.0, "bank {bank}");
            } else {
                assert_eq!(*cycles, 0.0, "bank {bank}");
            }
        }
    }

    #[test]
    fn second_campaign_accumulates_wear() {
        let (mut c, placement, mut wear) = one_placement();
        CampaignScheduler::run(&mut c, &placement, &mut wear, 0.0);
        CampaignScheduler::run(&mut c, &placement, &mut wear, 0.0);
        assert_eq!(wear.max_cycles(), 2.0);
    }

    #[test]
    fn rewarm_displacement_matches_resident_lines() {
        let (mut c, placement, mut wear) = one_placement();
        // Fresh cache: nothing resident, so nothing displaced or rewarmed.
        let report = CampaignScheduler::run(&mut c, &placement, &mut wear, 0.0);
        assert_eq!(report.lines_displaced, 0);
        assert_eq!(report.rewarm_s, 0.0);
        // Warm a line into a sub-array the placement covers, then reprogram.
        let (bank, sa) = placement.layout.placements[0].pos_slot;
        let mut led = crate::cell::timing::EnergyLedger::new();
        let li = sa * c.slice.geom.rows_per_subarray;
        c.slice.banks[bank].write_line(li, [9u8; 64], &mut led);
        let report = CampaignScheduler::run(&mut c, &placement, &mut wear, 0.0);
        assert_eq!(report.lines_displaced, 1);
        assert!(report.rewarm_s > 0.0);
        // Rewarm restored residency, so the next campaign displaces (and
        // reloads) the same line again instead of under-counting to zero.
        let again = CampaignScheduler::run(&mut c, &placement, &mut wear, 0.0);
        assert_eq!(again.lines_displaced, 1);
        assert!(again.rewarm_s > 0.0);
    }
}
