//! Fleet router + admission controller: [`crate::coordinator::Router`]
//! generalized to (tenant, replica) pairs with per-tenant QoS deadlines.
//!
//! The fleet router runs against the *simulated* clock: assigning a
//! request computes its start/completion against the chosen replica's
//! queue, so the whole multi-tenant simulation is deterministic. The
//! admission controller rejects requests whose projected completion
//! cannot meet the tenant's deadline — shedding load early instead of
//! blowing the tail.

/// Serving availability of one replica.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReplicaHealth {
    /// Accepting traffic.
    Serving,
    /// Finishing in-flight work ahead of a programming campaign.
    Draining,
    /// Weights being reprogrammed (destructive; cannot serve).
    Programming,
}

/// Load state of one fleet replica on the *simulated* clock — the
/// counterpart of [`crate::coordinator::router::ReplicaState`], which
/// tracks in-flight batches on the wall clock. Here the queue is fully
/// described by `busy_until`, so there is no inflight counter to keep
/// honest.
#[derive(Clone, Copy, Debug, Default)]
pub struct FleetReplicaState {
    /// Requests served (assigned) so far.
    pub served: u64,
    /// Simulated time until which the replica's queue is committed.
    pub busy_until: f64,
}

/// One (tenant, replica) serving endpoint.
#[derive(Clone, Debug)]
pub struct FleetReplica {
    /// Load state on the simulated clock.
    pub state: FleetReplicaState,
    /// Availability.
    pub health: ReplicaHealth,
}

impl FleetReplica {
    fn idle() -> FleetReplica {
        FleetReplica { state: FleetReplicaState::default(), health: ReplicaHealth::Serving }
    }
}

/// Router over every tenant's replica set.
///
/// # Examples
///
/// Assignments queue behind the earliest-available serving replica on the
/// simulated clock:
///
/// ```
/// use nvm_in_cache::fleet::{FleetRouter, ReplicaHealth};
///
/// let mut router = FleetRouter::new(&[2]);
/// let (first, start, _) = router.assign(0, 0.0, 1.0).unwrap();
/// assert_eq!(start, 0.0);
/// let (second, _, _) = router.assign(0, 0.0, 1.0).unwrap();
/// assert_ne!(first, second, "idle sibling picked over the busy replica");
///
/// // A replica under reprogramming stops receiving traffic.
/// router.set_health(0, 0, ReplicaHealth::Programming);
/// router.set_health(0, 1, ReplicaHealth::Programming);
/// assert!(router.assign(0, 0.0, 1.0).is_none());
/// ```
pub struct FleetRouter {
    /// Replica states, indexed `[tenant][replica]`.
    pub tenants: Vec<Vec<FleetReplica>>,
}

impl FleetRouter {
    /// Router with `replicas_per_tenant[t]` idle replicas for tenant `t`.
    pub fn new(replicas_per_tenant: &[usize]) -> FleetRouter {
        assert!(!replicas_per_tenant.is_empty());
        FleetRouter {
            tenants: replicas_per_tenant
                .iter()
                .map(|&n| {
                    assert!(n > 0);
                    (0..n).map(|_| FleetReplica::idle()).collect()
                })
                .collect(),
        }
    }

    /// Earliest time a `tenant` request arriving at `now` could start
    /// (None when no replica is serving).
    pub fn earliest_start(&self, tenant: usize, now: f64) -> Option<f64> {
        self.tenants[tenant]
            .iter()
            .filter(|r| r.health == ReplicaHealth::Serving)
            .map(|r| r.state.busy_until.max(now))
            .min_by(|a, b| a.total_cmp(b))
    }

    /// Assign one request arriving at `now` needing `service_s` of replica
    /// time: picks the serving replica with the earliest availability
    /// (ties by index — deterministic), queues the request behind it, and
    /// returns `(replica, start, completion)`. `None` when every replica
    /// is draining/programming.
    pub fn assign(
        &mut self,
        tenant: usize,
        now: f64,
        service_s: f64,
    ) -> Option<(usize, f64, f64)> {
        self.assign_with_occupancy(tenant, now, service_s, service_s)
    }

    /// [`Self::assign`] for pipelined (shard-chain) replicas, where the
    /// time a request *occupies* the replica differs from its end-to-end
    /// latency: a shard pipeline accepts a new request every
    /// [`crate::fleet::shard::ShardPipelineCost::cycle_s`] (the slowest
    /// stage or hop) even though each request takes the full fill-path
    /// `latency_s` to complete. Books the replica for `occupancy_s`
    /// (`busy_until = start + occupancy_s`) and reports completion at
    /// `start + service_s`. With `occupancy_s == service_s` this is
    /// exactly [`Self::assign`].
    pub fn assign_with_occupancy(
        &mut self,
        tenant: usize,
        now: f64,
        occupancy_s: f64,
        service_s: f64,
    ) -> Option<(usize, f64, f64)> {
        let replicas = &mut self.tenants[tenant];
        let idx = (0..replicas.len())
            .filter(|&i| replicas[i].health == ReplicaHealth::Serving)
            .min_by(|&a, &b| {
                replicas[a]
                    .state
                    .busy_until
                    .total_cmp(&replicas[b].state.busy_until)
                    .then(a.cmp(&b))
            })?;
        let r = &mut replicas[idx];
        let start = r.state.busy_until.max(now);
        let completion = start + service_s;
        r.state.busy_until = start + occupancy_s;
        r.state.served += 1;
        Some((idx, start, completion))
    }

    /// Change a replica's availability.
    pub fn set_health(&mut self, tenant: usize, replica: usize, health: ReplicaHealth) {
        self.tenants[tenant][replica].health = health;
    }

    /// Replicas of `tenant` currently accepting traffic.
    pub fn serving_count(&self, tenant: usize) -> usize {
        self.tenants[tenant]
            .iter()
            .filter(|r| r.health == ReplicaHealth::Serving)
            .count()
    }

    /// Requests served for one tenant.
    pub fn tenant_served(&self, tenant: usize) -> u64 {
        self.tenants[tenant].iter().map(|r| r.state.served).sum()
    }

    /// Requests served fleet-wide.
    pub fn total_served(&self) -> u64 {
        (0..self.tenants.len()).map(|t| self.tenant_served(t)).sum()
    }
}

/// Deadline-aware admission controller, one entry per tenant.
pub struct AdmissionController {
    /// Estimated service time per tenant request (s).
    pub est_service_s: Vec<f64>,
    /// Per-tenant deadline (s).
    pub deadline_s: Vec<f64>,
    /// Requests rejected per tenant.
    pub rejected: Vec<u64>,
}

impl AdmissionController {
    /// Controller from per-tenant service estimates and deadlines.
    pub fn new(est_service_s: Vec<f64>, deadline_s: Vec<f64>) -> AdmissionController {
        assert_eq!(est_service_s.len(), deadline_s.len());
        let n = est_service_s.len();
        AdmissionController { est_service_s, deadline_s, rejected: vec![0; n] }
    }

    /// Admit a `tenant` request arriving at `now` iff its projected
    /// completion (earliest replica availability + estimated service) can
    /// meet the deadline. Rejections are counted.
    pub fn admit(&mut self, router: &FleetRouter, tenant: usize, now: f64) -> bool {
        let ok = match router.earliest_start(tenant, now) {
            Some(start) => start - now + self.est_service_s[tenant] <= self.deadline_s[tenant],
            None => false,
        };
        if !ok {
            self.rejected[tenant] += 1;
        }
        ok
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn assign_balances_identical_replicas() {
        let mut r = FleetRouter::new(&[3]);
        let a = r.assign(0, 0.0, 1.0).unwrap().0;
        let b = r.assign(0, 0.0, 1.0).unwrap().0;
        let c = r.assign(0, 0.0, 1.0).unwrap().0;
        let mut seen = vec![a, b, c];
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1, 2]);
    }

    #[test]
    fn assign_queues_behind_busy_replica() {
        let mut r = FleetRouter::new(&[1]);
        let (_, s1, c1) = r.assign(0, 0.0, 2.0).unwrap();
        assert_eq!((s1, c1), (0.0, 2.0));
        let (_, s2, c2) = r.assign(0, 1.0, 2.0).unwrap();
        assert_eq!((s2, c2), (2.0, 4.0), "second request waits for the first");
        // A late arrival after the queue empties starts immediately.
        let (_, s3, _) = r.assign(0, 10.0, 2.0).unwrap();
        assert_eq!(s3, 10.0);
    }

    #[test]
    fn draining_replicas_are_skipped() {
        let mut r = FleetRouter::new(&[2]);
        r.set_health(0, 0, ReplicaHealth::Draining);
        for _ in 0..5 {
            assert_eq!(r.assign(0, 0.0, 1.0).unwrap().0, 1);
        }
        r.set_health(0, 1, ReplicaHealth::Programming);
        assert!(r.assign(0, 0.0, 1.0).is_none(), "no serving replica left");
        assert_eq!(r.serving_count(0), 0);
    }

    #[test]
    fn occupancy_books_less_than_service() {
        let mut r = FleetRouter::new(&[1]);
        // Pipelined replica: each request occupies the chain for 1.0 s
        // (its cycle time) but completes after 3.0 s (fill-path latency).
        let (_, s1, c1) = r.assign_with_occupancy(0, 0.0, 1.0, 3.0).unwrap();
        assert_eq!((s1, c1), (0.0, 3.0));
        // The next request enters the pipeline one cycle later, not after
        // the first one's full latency.
        let (_, s2, c2) = r.assign_with_occupancy(0, 0.0, 1.0, 3.0).unwrap();
        assert_eq!((s2, c2), (1.0, 4.0));
        // Equal occupancy/service degenerates to plain assign.
        let mut plain = FleetRouter::new(&[1]);
        let a = plain.assign(0, 0.0, 2.0).unwrap();
        let mut via = FleetRouter::new(&[1]);
        let b = via.assign_with_occupancy(0, 0.0, 2.0, 2.0).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn tenants_are_independent() {
        let mut r = FleetRouter::new(&[1, 2]);
        let _ = r.assign(0, 0.0, 5.0);
        // Tenant 1's replicas are untouched by tenant 0's load.
        let (_, start, _) = r.assign(1, 0.0, 1.0).unwrap();
        assert_eq!(start, 0.0);
        assert_eq!(r.tenant_served(0), 1);
        assert_eq!(r.tenant_served(1), 1);
        assert_eq!(r.total_served(), 2);
    }

    #[test]
    fn admission_rejects_past_deadline() {
        let mut r = FleetRouter::new(&[1]);
        let mut ac = AdmissionController::new(vec![1.0], vec![2.5]);
        // Empty queue: 0 wait + 1.0 service ≤ 2.5 ⇒ admit.
        assert!(ac.admit(&r, 0, 0.0));
        let _ = r.assign(0, 0.0, 1.0);
        let _ = r.assign(0, 0.0, 1.0);
        // Queue delay 2.0 + 1.0 service > 2.5 ⇒ reject.
        assert!(!ac.admit(&r, 0, 0.0));
        assert_eq!(ac.rejected[0], 1);
        // Later, the queue has drained enough.
        assert!(ac.admit(&r, 0, 1.0));
    }

    #[test]
    fn admission_rejects_when_all_replicas_down() {
        let mut r = FleetRouter::new(&[1]);
        r.set_health(0, 0, ReplicaHealth::Programming);
        let mut ac = AdmissionController::new(vec![0.1], vec![10.0]);
        assert!(!ac.admit(&r, 0, 0.0));
    }
}
