//! Model-parallel layer sharding across cache slices: partition one
//! network's layers (and, for over-wide layers, output-filter ranges)
//! into segments that each fit a slice, cost the inter-slice activation
//! hops, and decide replica-parallel vs shard-parallel per tenant.
//!
//! Three pieces:
//!
//! * [`ShardPlan`] — capacity-greedy partition of a layer list into
//!   contiguous segments via repeated [`NetworkLayout::place_from`]
//!   trials (PIM-DRAM-style: a single layer wider than a whole slice is
//!   further split along its output-filter axis, so *any* layer admits).
//! * [`TransferLink`] — the inter-slice hop cost model. The activation
//!   tensor at the cut (`elems × act_bits` bits, packed into cache
//!   lines) moves slice-to-slice at the cache's line-move cost
//!   ([`OpKind::CacheLineMove`] — the same primitive
//!   `BankScheduler::batch_cost` charges for flush/reload movement), so
//!   hop latency/energy sit in the same unit system as the
//!   `layer_costs` pipeline stages they interleave with.
//! * [`ShardPipelineCost`] / [`choose_mode`] — per-shard stage costs +
//!   hops rolled into the two numbers the fleet schedules on: `latency_s`
//!   (end-to-end fill: one request walks every stage and hop) and
//!   `cycle_s` (pipeline cadence: the bottleneck stage-or-hop, what a
//!   shard chain's occupancy costs per request once the pipeline is
//!   full). The replica-vs-shard decision: shard only when a whole
//!   replica does not fit one slice, or when the pipelined cadence meets
//!   a QoS deadline that a single slice's sojourn time cannot.
//!
//! The execution half (bit-identical pipelined stepping of a
//! [`crate::pim::CompiledNet`]) is `pim::shard_exec`; this module is the
//! placement/cost half the placer, router, fleet sim, and front door
//! consume. See ARCHITECTURE.md §fleet/shard and PERFORMANCE.md §10.

use crate::cache::addr::Geometry;
use crate::cache::controller::PimIntegration;
use crate::cell::timing::OpKind;
use crate::coordinator::scheduler::{BankScheduler, ExecutionCost};
use crate::mapping::conv_mapper::ConvShape;
use crate::mapping::layout::NetworkLayout;
use crate::perf::model::MacroModel;
use crate::{Error, Result};

/// One contiguous segment of a sharded network: the layers (or the
/// output-filter slice of a single over-wide layer) that live together
/// on one cache slice.
#[derive(Clone, Debug)]
pub struct ShardSegment {
    /// Position in the shard chain (0 = the segment that sees the input).
    pub shard: usize,
    /// Half-open index range into the tenant's full layer list.
    pub layer_range: (usize, usize),
    /// `Some((lo, hi))` when this segment carries output filters
    /// `lo..hi` of the single layer in `layer_range` (an over-wide layer
    /// split along its filter axis); `None` for whole-layer segments.
    pub filter_range: Option<(usize, usize)>,
    /// The shapes this segment actually places (for a filter split, the
    /// layer with `n` narrowed to the chunk).
    pub layers: Vec<ConvShape>,
    /// Physical slots this segment consumes on its slice (2 per tile).
    pub slots: usize,
}

/// A partition of one network into shard segments, each guaranteed to
/// fit an (empty) slice of the geometry it was planned for.
#[derive(Clone, Debug)]
pub struct ShardPlan {
    /// The segments, in execution order.
    pub segments: Vec<ShardSegment>,
    /// Total slots across all segments.
    pub total_slots: usize,
}

/// Slots a shape list needs on an empty slice, or `None` if it cannot
/// fit even alone.
fn slots_needed(shapes: &[ConvShape], geom: &Geometry) -> Option<usize> {
    NetworkLayout::place(shapes, geom.banks_per_slice, geom.subarrays_per_bank)
        .map(|l| l.next_slot())
}

impl ShardPlan {
    /// Capacity-greedy partition: walk the layers in execution order,
    /// extending the current segment while a trial
    /// [`NetworkLayout::place_from`] still fits one slice, cutting when
    /// it would overflow. A single layer that overflows an *empty* slice
    /// is split along its output-filter (`n`) axis into the fewest
    /// equal chunks that fit. Errors when more than `max_shards`
    /// segments would be needed, or when a layer cannot be split finely
    /// enough (its per-filter footprint alone exceeds a slice).
    pub fn partition(
        layers: &[ConvShape],
        geom: &Geometry,
        max_shards: usize,
    ) -> Result<ShardPlan> {
        if layers.is_empty() {
            return Err(Error::Config("cannot shard an empty layer list".into()));
        }
        let mut segments: Vec<ShardSegment> = Vec::new();
        let mut cur: Vec<ConvShape> = Vec::new();
        let mut cur_start = 0usize;
        let mut flush = |cur: &mut Vec<ConvShape>,
                         cur_start: &mut usize,
                         end: usize,
                         segments: &mut Vec<ShardSegment>| {
            if cur.is_empty() {
                return;
            }
            let slots = slots_needed(cur, geom)
                .expect("segment grown under a fits-one-slice invariant");
            segments.push(ShardSegment {
                shard: segments.len(),
                layer_range: (*cur_start, end),
                filter_range: None,
                layers: std::mem::take(cur),
                slots,
            });
            *cur_start = end;
        };
        for (li, &shape) in layers.iter().enumerate() {
            if slots_needed(&[shape], geom).is_none() {
                // Over-wide layer: flush, then filter-split it.
                flush(&mut cur, &mut cur_start, li, &mut segments);
                let parts = (2..=shape.n)
                    .find(|&p| {
                        let chunk = ConvShape { n: shape.n.div_ceil(p), ..shape };
                        slots_needed(&[chunk], geom).is_some()
                    })
                    .ok_or_else(|| {
                        Error::Config(format!(
                            "layer {li} cannot be filter-split to fit a slice \
                             (single-filter footprint exceeds capacity)"
                        ))
                    })?;
                for j in 0..parts {
                    let (lo, hi) = (j * shape.n / parts, (j + 1) * shape.n / parts);
                    let chunk = ConvShape { n: hi - lo, ..shape };
                    let slots = slots_needed(&[chunk], geom)
                        .expect("chunk size chosen to fit a slice");
                    segments.push(ShardSegment {
                        shard: segments.len(),
                        layer_range: (li, li + 1),
                        filter_range: Some((lo, hi)),
                        layers: vec![chunk],
                        slots,
                    });
                }
                cur_start = li + 1;
                continue;
            }
            cur.push(shape);
            if slots_needed(&cur, geom).is_none() {
                // Overflowed: cut before this layer and restart from it.
                let shape = cur.pop().expect("just pushed");
                flush(&mut cur, &mut cur_start, li, &mut segments);
                cur.push(shape);
            }
        }
        flush(&mut cur, &mut cur_start, layers.len(), &mut segments);
        if segments.len() > max_shards {
            return Err(Error::Config(format!(
                "network needs {} shards but max_shards is {max_shards}",
                segments.len()
            )));
        }
        let total_slots = segments.iter().map(|s| s.slots).sum();
        Ok(ShardPlan { segments, total_slots })
    }

    /// Slot-balanced partition into *exactly* `n_shards` segments for a
    /// network that may well fit one slice — the deadline-driven shard
    /// mode, where splitting is about pipeline cadence, not capacity.
    /// Cuts greedily at ~`total/n_shards` slot targets; errors when the
    /// network has fewer layers than shards.
    pub fn partition_into(
        layers: &[ConvShape],
        geom: &Geometry,
        n_shards: usize,
    ) -> Result<ShardPlan> {
        if n_shards == 0 || n_shards > layers.len() {
            return Err(Error::Config(format!(
                "cannot split {} layers into {n_shards} shards",
                layers.len()
            )));
        }
        let total: usize = layers
            .iter()
            .map(|s| slots_needed(&[*s], geom).unwrap_or(usize::MAX))
            .sum();
        if total == usize::MAX {
            // An over-wide layer present: fall back to the capacity path.
            return Self::partition(layers, geom, n_shards);
        }
        let target = total.div_ceil(n_shards);
        let mut segments: Vec<ShardSegment> = Vec::new();
        let mut cur: Vec<ConvShape> = Vec::new();
        let mut cur_start = 0usize;
        let mut acc = 0usize;
        for (li, &shape) in layers.iter().enumerate() {
            cur.push(shape);
            acc += slots_needed(&[shape], geom).expect("checked above");
            let remaining_layers = layers.len() - li - 1;
            let remaining_segs = n_shards - segments.len() - 1;
            if (acc >= target && remaining_segs > 0) || remaining_layers == remaining_segs {
                let slots = slots_needed(&cur, geom).ok_or_else(|| {
                    Error::Config(format!(
                        "balanced segment ending at layer {li} does not fit one slice"
                    ))
                })?;
                segments.push(ShardSegment {
                    shard: segments.len(),
                    layer_range: (cur_start, li + 1),
                    filter_range: None,
                    layers: std::mem::take(&mut cur),
                    slots,
                });
                cur_start = li + 1;
                acc = 0;
            }
        }
        debug_assert_eq!(segments.len(), n_shards);
        let total_slots = segments.iter().map(|s| s.slots).sum();
        Ok(ShardPlan { segments, total_slots })
    }

    /// Number of shard segments.
    pub fn shards(&self) -> usize {
        self.segments.len()
    }

    /// True when the plan actually splits the network (2+ segments).
    pub fn is_sharded(&self) -> bool {
        self.segments.len() > 1
    }

    /// Activation elements (per image) crossing the cut between segment
    /// `i` and segment `i+1`. For a whole-layer cut this is the last
    /// layer's output tensor (`n × ow²`). For a cut between two filter
    /// chunks of the *same* layer, the downstream chunk needs the
    /// layer's full input broadcast (`d × w²`) plus the partial outputs
    /// accumulated so far (`hi × ow²`), which ride along to be gathered
    /// at the chain's next whole-layer consumer.
    pub fn cut_elems(&self, i: usize) -> usize {
        let a = &self.segments[i];
        let b = &self.segments[i + 1];
        let last = *a.layers.last().expect("segments are non-empty");
        let ow = last.output_width();
        let filter_sibling = a.layer_range == b.layer_range
            && a.filter_range.is_some()
            && b.filter_range.is_some();
        if filter_sibling {
            let (_, hi) = a.filter_range.expect("checked filter sibling");
            last.d * last.w * last.w + hi * ow * ow
        } else {
            last.n * ow * ow
        }
    }

    /// The inter-slice transfer links (one per adjacent segment pair)
    /// for a single image at `act_bits` activation precision.
    pub fn links(&self, model: &MacroModel, geom: &Geometry) -> Vec<TransferLink> {
        (0..self.segments.len().saturating_sub(1))
            .map(|i| {
                TransferLink::for_activation(
                    i,
                    i + 1,
                    self.cut_elems(i),
                    model.act_bits,
                    geom.line_bytes,
                )
            })
            .collect()
    }

    /// Full pipeline cost of `batch` images through the shard chain:
    /// per-segment compute stages (a [`BankScheduler`] per segment over
    /// its own slice) plus the activation hops between them.
    pub fn pipeline_cost(
        &self,
        geom: &Geometry,
        mode: PimIntegration,
        batch: usize,
    ) -> Result<ShardPipelineCost> {
        let model = MacroModel::default();
        let mut stages = Vec::with_capacity(self.segments.len());
        for seg in &self.segments {
            let mut sched = BankScheduler::new(seg.layers.clone(), *geom, mode)
                .ok_or_else(|| {
                    Error::Config(format!(
                        "shard segment {} does not fit one slice (plan/geometry mismatch)",
                        seg.shard
                    ))
                })?;
            sched.program_network();
            let mut stage = ExecutionCost::default();
            for lc in sched.layer_costs(batch) {
                stage.ops += lc.ops;
                stage.latency_s += lc.latency_s;
                stage.energy_j += lc.energy_j;
            }
            stages.push(stage);
        }
        let links: Vec<TransferLink> = self
            .links(&model, geom)
            .into_iter()
            .map(|l| l.scaled(batch))
            .collect();
        let compute_lat: f64 = stages.iter().map(|s| s.latency_s).sum();
        let compute_energy: f64 = stages.iter().map(|s| s.energy_j).sum();
        let ops: f64 = stages.iter().map(|s| s.ops).sum();
        let transfer_latency_s: f64 = links.iter().map(|l| l.latency_s).sum();
        let transfer_energy_j: f64 = links.iter().map(|l| l.energy_j).sum();
        let cycle_s = stages
            .iter()
            .map(|s| s.latency_s)
            .chain(links.iter().map(|l| l.latency_s))
            .fold(0.0f64, f64::max);
        Ok(ShardPipelineCost {
            stages,
            links,
            latency_s: compute_lat + transfer_latency_s,
            cycle_s,
            energy_j: compute_energy + transfer_energy_j,
            ops,
            transfer_latency_s,
            transfer_energy_j,
        })
    }
}

/// One inter-slice activation hop: the tensor crossing a shard cut,
/// packed into cache lines and moved at the line-move cost.
#[derive(Clone, Copy, Debug)]
pub struct TransferLink {
    /// Producing shard.
    pub from_shard: usize,
    /// Consuming shard.
    pub to_shard: usize,
    /// Activation elements crossing the cut.
    pub elems: usize,
    /// Payload bytes (`elems × act_bits` bits, byte-packed).
    pub bytes: u64,
    /// Cache lines moved (`bytes / line_bytes`, rounded up).
    pub lines: u64,
    /// Hop latency (s): `lines × t(CacheLineMove)`.
    pub latency_s: f64,
    /// Hop energy (J): `lines × e(CacheLineMove)`.
    pub energy_j: f64,
}

impl TransferLink {
    /// Cost one activation tensor's hop between two slices.
    pub fn for_activation(
        from_shard: usize,
        to_shard: usize,
        elems: usize,
        act_bits: u32,
        line_bytes: usize,
    ) -> TransferLink {
        let bits = elems as u64 * act_bits as u64;
        let bytes = bits.div_ceil(8);
        let lines = bytes.div_ceil(line_bytes as u64).max(1);
        let (t, e) = OpKind::CacheLineMove.cost();
        TransferLink {
            from_shard,
            to_shard,
            elems,
            bytes,
            lines,
            latency_s: lines as f64 * t,
            energy_j: lines as f64 * e,
        }
    }

    /// The same link carrying `batch` images' activations.
    pub fn scaled(&self, batch: usize) -> TransferLink {
        let b = batch as u64;
        TransferLink {
            elems: self.elems * batch,
            bytes: self.bytes * b,
            lines: self.lines * b,
            latency_s: self.latency_s * batch as f64,
            energy_j: self.energy_j * batch as f64,
            ..*self
        }
    }
}

/// Cost roll-up of one request batch through a shard chain.
#[derive(Clone, Debug)]
pub struct ShardPipelineCost {
    /// Per-shard compute stage cost (the tandem stages).
    pub stages: Vec<ExecutionCost>,
    /// Per-hop transfer cost between adjacent shards.
    pub links: Vec<TransferLink>,
    /// End-to-end latency of one request: every stage plus every hop
    /// (the pipeline *fill* path — what a single request experiences).
    pub latency_s: f64,
    /// Pipeline cadence: the bottleneck stage-or-hop latency — what the
    /// chain's occupancy costs per request once the pipeline is full.
    pub cycle_s: f64,
    /// Total energy (compute + transfer).
    pub energy_j: f64,
    /// MAC ops.
    pub ops: f64,
    /// Latency attributable to inter-slice hops alone.
    pub transfer_latency_s: f64,
    /// Energy attributable to inter-slice hops alone.
    pub transfer_energy_j: f64,
}

/// How a tenant's replicas should be laid out.
#[derive(Clone, Debug)]
pub enum PlacementMode {
    /// Whole replicas, each on one slice (the PR 3 default).
    Replica,
    /// Shard-parallel: each replica is a chain of segments across
    /// slices, served as a pipeline.
    Sharded(ShardPlan),
}

impl PlacementMode {
    /// Shard count (1 for replica-parallel).
    pub fn shards(&self) -> usize {
        match self {
            PlacementMode::Replica => 1,
            PlacementMode::Sharded(p) => p.shards(),
        }
    }
}

/// M/M/1-flavored sojourn-time estimate: service plus the utilization
/// wait `ρ/(1−ρ)` of the occupancy each request holds. `occupancy_s` is
/// the time a request keeps the resource busy (the full service for a
/// single slice; the pipeline cycle for a shard chain), `latency_s` the
/// time it takes to come back.
fn sojourn(latency_s: f64, occupancy_s: f64, utilization: f64) -> f64 {
    if utilization >= 1.0 {
        return f64::INFINITY;
    }
    latency_s + occupancy_s * utilization / (1.0 - utilization)
}

/// The replica-vs-shard decision for one tenant: shard only when (a) a
/// whole replica does not fit one slice, or (b) it fits but its
/// single-slice sojourn time misses the QoS deadline while some
/// pipelined split's sojourn (end-to-end latency + cadence-scaled wait)
/// meets it. Otherwise replica-parallel wins (sharding costs hops and
/// slices without buying anything).
pub fn choose_mode(
    layers: &[ConvShape],
    geom: &Geometry,
    deadline_s: f64,
    utilization: f64,
    max_shards: usize,
) -> Result<PlacementMode> {
    let fits = NetworkLayout::place(layers, geom.banks_per_slice, geom.subarrays_per_bank)
        .is_some();
    if !fits {
        return Ok(PlacementMode::Sharded(ShardPlan::partition(layers, geom, max_shards)?));
    }
    // Fits one slice: estimate whether a single slice meets the deadline.
    let mut whole = BankScheduler::new(layers.to_vec(), *geom, PimIntegration::Retained)
        .expect("placement feasibility just verified");
    whole.program_network();
    let svc = whole.batch_cost(1).latency_s;
    if sojourn(svc, svc, utilization) <= deadline_s {
        return Ok(PlacementMode::Replica);
    }
    // Deadline-driven: the smallest split whose pipelined sojourn makes it.
    for n in 2..=max_shards.min(layers.len()) {
        let Ok(plan) = ShardPlan::partition_into(layers, geom, n) else { continue };
        let Ok(cost) = plan.pipeline_cost(geom, PimIntegration::Retained, 1) else { continue };
        if sojourn(cost.latency_s, cost.cycle_s, utilization) <= deadline_s {
            return Ok(PlacementMode::Sharded(plan));
        }
    }
    // No split helps either; keep the simple layout.
    Ok(PlacementMode::Replica)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wide_layers() -> Vec<ConvShape> {
        BankScheduler::resnet18_layers(24)
    }

    #[test]
    fn wide_resnet_overflows_one_slice_and_partitions() {
        let geom = Geometry::default();
        let layers = wide_layers();
        assert!(slots_needed(&layers, &geom).is_none(), "w24 must overflow one slice");
        let plan = ShardPlan::partition(&layers, &geom, 4).unwrap();
        assert!(plan.is_sharded());
        let capacity = geom.banks_per_slice * geom.subarrays_per_bank;
        for seg in &plan.segments {
            assert!(seg.slots <= capacity, "segment {} overflows", seg.shard);
            assert!(!seg.layers.is_empty());
        }
        // Segments tile the layer list contiguously.
        let mut next = 0;
        for seg in &plan.segments {
            assert_eq!(seg.layer_range.0, next);
            next = seg.layer_range.1.max(next);
        }
        assert_eq!(next, layers.len());
    }

    #[test]
    fn partition_is_deterministic() {
        let geom = Geometry::default();
        let a = ShardPlan::partition(&wide_layers(), &geom, 4).unwrap();
        let b = ShardPlan::partition(&wide_layers(), &geom, 4).unwrap();
        assert_eq!(a.shards(), b.shards());
        assert_eq!(a.total_slots, b.total_slots);
        for (x, y) in a.segments.iter().zip(b.segments.iter()) {
            assert_eq!(x.layer_range, y.layer_range);
            assert_eq!(x.slots, y.slots);
        }
    }

    #[test]
    fn over_wide_single_layer_filter_splits() {
        // On the tiny geometry (8 slots) a 3×3 64→64 layer needs far
        // more than one slice; partition must split its filters.
        let geom = Geometry::tiny();
        let layers = vec![ConvShape { k: 3, d: 64, n: 64, w: 8, stride: 1 }];
        let plan = ShardPlan::partition(&layers, &geom, 64).unwrap();
        assert!(plan.shards() >= 2);
        let mut covered = 0;
        for seg in &plan.segments {
            let (lo, hi) = seg.filter_range.expect("filter-split segments");
            assert_eq!(lo, covered, "filter chunks must be contiguous");
            covered = hi;
            assert_eq!(seg.layers[0].n, hi - lo);
        }
        assert_eq!(covered, 64);
    }

    #[test]
    fn transfer_link_packs_lines() {
        // 1000 elems × 4 bits = 500 bytes = 8 lines of 64 B.
        let l = TransferLink::for_activation(0, 1, 1000, 4, 64);
        assert_eq!(l.bytes, 500);
        assert_eq!(l.lines, 8);
        let (t, e) = OpKind::CacheLineMove.cost();
        assert!((l.latency_s - 8.0 * t).abs() < 1e-18);
        assert!((l.energy_j - 8.0 * e).abs() < 1e-18);
        let s = l.scaled(3);
        assert_eq!(s.lines, 24);
        assert!((s.latency_s - 3.0 * l.latency_s).abs() < 1e-18);
    }

    #[test]
    fn pipeline_cost_decomposes() {
        let geom = Geometry::default();
        let plan = ShardPlan::partition(&wide_layers(), &geom, 4).unwrap();
        let cost = plan.pipeline_cost(&geom, PimIntegration::Retained, 1).unwrap();
        assert_eq!(cost.stages.len(), plan.shards());
        assert_eq!(cost.links.len(), plan.shards() - 1);
        assert!(cost.transfer_latency_s > 0.0);
        let stage_sum: f64 = cost.stages.iter().map(|s| s.latency_s).sum();
        assert!((cost.latency_s - (stage_sum + cost.transfer_latency_s)).abs() < 1e-15);
        // Cadence is the bottleneck, strictly under the serial total.
        assert!(cost.cycle_s < cost.latency_s);
        assert!(cost.cycle_s >= cost.latency_s / (plan.shards() + 1) as f64);
    }

    #[test]
    fn sharded_stage_costs_match_unsharded_layer_costs() {
        // The same layers, split or not, must charge the same compute:
        // sharding adds hops, never changes a layer's stage cost.
        let geom = Geometry::default();
        let layers = BankScheduler::resnet18_layers(16);
        let mut whole =
            BankScheduler::new(layers.clone(), geom, PimIntegration::Retained).unwrap();
        whole.program_network();
        let whole_lat: f64 = whole.layer_costs(1).iter().map(|c| c.latency_s).sum();
        let plan = ShardPlan::partition_into(&layers, &geom, 3).unwrap();
        let cost = plan.pipeline_cost(&geom, PimIntegration::Retained, 1).unwrap();
        let stage_sum: f64 = cost.stages.iter().map(|s| s.latency_s).sum();
        assert!((stage_sum - whole_lat).abs() / whole_lat < 1e-12);
    }

    #[test]
    fn choose_mode_shards_only_when_needed() {
        let geom = Geometry::default();
        // Width 16 fits and meets its deadline comfortably: replica.
        let fitting = BankScheduler::resnet18_layers(16);
        let mode = choose_mode(&fitting, &geom, 0.05, 0.4, 4).unwrap();
        assert!(matches!(mode, PlacementMode::Replica));
        // Width 24 cannot fit: sharded regardless of deadline.
        let mode = choose_mode(&wide_layers(), &geom, 10.0, 0.1, 4).unwrap();
        match mode {
            PlacementMode::Sharded(p) => assert!(p.is_sharded()),
            PlacementMode::Replica => panic!("over-capacity tenant must shard"),
        }
    }

    #[test]
    fn choose_mode_can_shard_for_deadline() {
        let geom = Geometry::default();
        let fitting = BankScheduler::resnet18_layers(16);
        let mut whole =
            BankScheduler::new(fitting.clone(), geom, PimIntegration::Retained).unwrap();
        whole.program_network();
        let svc = whole.batch_cost(1).latency_s;
        // A deadline between the pipelined sojourn and the single-slice
        // sojourn at high utilization forces the deadline-driven branch.
        let util = 0.9;
        let single = svc + svc * util / (1.0 - util);
        let deadline = single * 0.6;
        let mode = choose_mode(&fitting, &geom, deadline, util, 6).unwrap();
        if let PlacementMode::Sharded(p) = &mode {
            let cost = p.pipeline_cost(&geom, PimIntegration::Retained, 1).unwrap();
            let pipelined =
                cost.latency_s + cost.cycle_s * util / (1.0 - util);
            assert!(pipelined <= deadline, "chosen split must meet the deadline");
        }
        // Either outcome is legal only if consistent with the rule; a
        // replica answer here would mean no split met the deadline, but
        // the bottleneck cycle shrinks ~linearly with shard count, so a
        // split must exist.
        assert!(matches!(mode, PlacementMode::Sharded(_)), "pipelining should rescue QoS");
    }
}
