//! The fleet layer (L4): a multi-tenant, endurance-aware serving fabric
//! across many cache slices.
//!
//! The paper's end-state is a repurposed commodity LLC — which in any real
//! deployment is many slices serving many models, not one ResNet on one
//! replica set. This layer sits above [`crate::coordinator`] and owns the
//! fleet-wide concerns:
//!
//! * [`registry`] — the tenants: model topology/width, runtime variant,
//!   replica count, offered load, QoS deadline.
//! * [`placer`] — endurance-aware placement: packs each replica's tiles
//!   onto a slice via [`crate::mapping::layout`], wear-levels across
//!   slices/banks using per-bank RRAM write-cycle counters, and refuses
//!   placements that would exceed the
//!   [`crate::device::reliability::EnduranceModel`] budget.
//! * [`campaign`] — destructive weight-programming campaigns interleaved
//!   with live traffic: drain → program → rewarm, metered through
//!   [`crate::cache::CacheController`] and [`crate::cell::timing`].
//! * [`router`] — [`crate::coordinator::Router`] generalized to
//!   (tenant, replica) pairs, plus a deadline-aware admission controller.
//! * [`shard`] — model-parallel layer sharding: partition a network that
//!   does not fit one slice into per-slice segments (down to
//!   output-filter ranges for over-wide layers), cost the inter-slice
//!   activation hops, and decide replica-parallel vs shard-parallel per
//!   tenant. The bit-identical pipelined executor is
//!   [`crate::pim::shard_exec`].
//! * [`sim`] — the deterministic fleet simulator behind `repro fleet-sim`:
//!   seeded multi-tenant traffic, campaigns mid-run, and a report pinning
//!   per-tenant p50/p99, throughput, energy, bank wear, downtime, and
//!   shard-chain transfer attribution.
//!
//! See ARCHITECTURE.md §fleet and §fleet/shard, EXPERIMENTS.md E12/E16.

pub mod campaign;
pub mod placer;
pub mod registry;
pub mod router;
pub mod shard;
pub mod sim;

pub use campaign::{CampaignReport, CampaignScheduler};
pub use placer::{BankWear, EndurancePlacer, EndurancePolicy, FleetPlacement, ReplicaPlacement};
pub use registry::{ModelFamily, ModelRegistry, QosSpec, TenantSpec};
pub use router::{AdmissionController, FleetRouter, FleetReplicaState, ReplicaHealth};
pub use shard::{PlacementMode, ShardPipelineCost, ShardPlan, ShardSegment, TransferLink};
pub use sim::{FleetReport, FleetSim, FleetSimConfig, TenantReport};
