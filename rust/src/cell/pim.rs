//! PIM mode: the two-cycle compute-on-powerline dot-product (§III-C).
//!
//! Cycle 1 (left half): VDD1 is pulled to the WCC reference while VDD2
//! stays nominal; after a 1.5 ns settle, the IA is applied on WL1 for 1 ns
//! and the current on VDD1 is sampled; a 1 ns restore returns the supplies.
//! Cycle 2 mirrors this on the right half. The gated-GND signals V1/V2 are
//! *deasserted during the sampling window* — this is the discipline that
//! (a) avoids a BL→GND crowbar path and (b) preserves the latched data.
//!
//! A row whose cell stores Q = 1 contributes its IA×weight current on the
//! left line in cycle 1; a row with Q = 0 contributes on the right line in
//! cycle 2 — so the two cycles together produce the complete dot-product
//! *regardless of the cached data* (Fig. 5c), which is the paper's headline
//! retention property.

use crate::consts::{T_PIM_CYCLE, T_PIM_SAMPLE, VDD};

use super::bitcell::{BitCell, Side};
use super::timing::{EnergyLedger, OpKind};

/// PIM operating parameters.
#[derive(Clone, Copy, Debug)]
pub struct PimParams {
    /// WCC reference voltage the active power line is pulled to during the
    /// settle+sample window (V).
    pub v_ref: f64,
    /// Ablation flag: keep V1/V2 asserted (0.8 V) during the sampling
    /// window, violating the paper's gated-GND discipline. Causes crowbar
    /// current and, in cycle 2, loss of the stored bit for Q = 1 cells.
    pub skip_gated_gnd: bool,
}

impl Default for PimParams {
    fn default() -> Self {
        PimParams { v_ref: 0.30, skip_gated_gnd: false }
    }
}

/// Result of running both PIM cycles on one cell.
#[derive(Clone, Copy, Debug)]
pub struct PimCycleOutcome {
    /// Current sampled on VDD1 during cycle 1 (A).
    pub i_left: f64,
    /// Current sampled on VDD2 during cycle 2 (A).
    pub i_right: f64,
    /// Whether the SRAM bit survived both cycles.
    pub retained: bool,
    /// The logical dot-product contribution IA·w implied by the currents
    /// (1 ⇔ the active side carried an LRS-level current).
    pub product: bool,
    /// Crowbar (BL→GND) charge wasted, if the gated-GND discipline was
    /// violated (C).
    pub crowbar_charge: f64,
}

impl BitCell {
    /// Execute the full two-cycle PIM dot-product for input activation `ia`.
    ///
    /// Returns the sampled line currents and retention status. Energy for
    /// the *array-level* cycle is recorded by the sub-array (the per-cell
    /// share is not individually metered, matching how the paper reports
    /// array energy); this method records nothing in `ledger` unless the
    /// crowbar ablation wastes extra charge.
    pub fn pim_dot_product(
        &mut self,
        ia: bool,
        params: &PimParams,
        ledger: &mut EnergyLedger,
    ) -> PimCycleOutcome {
        let q_initial = self.q;
        let mut crowbar = 0.0;

        // ---- Cycle 1: left half computes, right half holds ----
        // Settle: VDD1 → v_ref. If Q = 1, M2 is on and node Q tracks VDD1
        // down to v_ref (dynamic retention: QB is held at 0 by M5 until V2
        // gates off; then it floats at 0 through the sample window).
        // Sample: WL1 = IA for 1 ns, V1 = V2 = 0.
        let i_left = self.pim_current(Side::Left, ia, params.v_ref);
        if params.skip_gated_gnd && ia {
            // Crowbar: BL (0.8 V) → M1 → Q → M3/M5 path → GND while both
            // the wordline and the footer are on. ~0.8 V across ~2 kΩ for
            // the 1 ns window.
            let i_crowbar = VDD / 2.0e3;
            crowbar += i_crowbar * T_PIM_SAMPLE;
            ledger.record(OpKind::DigitalPostOp); // placeholder cost is
                                                  // replaced below by explicit energy via crowbar_charge
        }
        // Restore: VDD1, V1 back to 0.8 V; Q recharges through M2 (Q = 1
        // case) or stays at 0 (Q = 0 case, M2 off).

        // ---- Cycle 2: right half computes, left half holds ----
        let i_right = self.pim_current(Side::Right, ia, params.v_ref);
        let mut retained = true;
        if params.skip_gated_gnd && ia && q_initial {
            // §III-C: in cycle 2 with Q = 1, WL2/BLB charge QB toward 1,
            // turning on M3. With V1 correctly gated off, Q floats and the
            // restore phase discharges QB again. If V1 stays on, M3
            // discharges Q while QB rises — the latch flips.
            self.q = false;
            retained = false;
            crowbar += VDD / 2.0e3 * T_PIM_SAMPLE;
        }

        debug_assert!(
            params.skip_gated_gnd || self.q == q_initial,
            "retention must hold under the correct sequencing"
        );

        // The cell's logical contribution: IA AND weight, carried on the
        // side selected by the stored data.
        let product = ia && self.weight_bit_of_active_side(q_initial);

        PimCycleOutcome { i_left, i_right, retained: retained && self.q == q_initial, product, crowbar_charge: crowbar }
    }

    fn weight_bit_of_active_side(&self, q: bool) -> bool {
        let side = if q { Side::Left } else { Side::Right };
        self.rram(side).state() == crate::device::RramState::Lrs
    }

    /// Wall-clock of the two PIM cycles (s).
    pub fn pim_latency() -> f64 {
        2.0 * T_PIM_CYCLE
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::Corner;

    fn run(q: bool, w: bool, ia: bool) -> (PimCycleOutcome, BitCell) {
        let mut c = BitCell::with_weight_bit(Corner::TT, w);
        c.q = q;
        let mut led = EnergyLedger::new();
        let out = c.pim_dot_product(ia, &PimParams::default(), &mut led);
        (out, c)
    }

    /// The four rows of Fig. 5(c): output current appears on the side
    /// selected by the stored data, with magnitude set by IA·w.
    #[test]
    fn fig5c_truth_table() {
        let lrs_scale = (VDD - 0.30) / crate::consts::R_LRS;
        // Q=1: result on left line.
        let (o, _) = run(true, true, true);
        assert!(o.i_left > 0.3 * lrs_scale, "i_left = {}", o.i_left);
        assert!(o.i_right < o.i_left / 50.0);
        assert!(o.product);
        // Q=0: result on right line.
        let (o, _) = run(false, true, true);
        assert!(o.i_right > 0.3 * lrs_scale);
        assert!(o.i_left < o.i_right / 50.0);
        assert!(o.product);
        // IA=0 ⇒ no current anywhere, product 0.
        let (o, _) = run(true, true, false);
        assert!(o.i_left < 1e-8 && o.i_right < 1e-8);
        assert!(!o.product);
        // w=0 (HRS) ⇒ small current, product 0.
        let (o, _) = run(true, false, true);
        assert!(o.i_left < lrs_scale / 20.0);
        assert!(!o.product);
    }

    #[test]
    fn data_retained_for_all_combinations() {
        for q in [false, true] {
            for w in [false, true] {
                for ia in [false, true] {
                    let (o, c) = run(q, w, ia);
                    assert!(o.retained, "q={q} w={w} ia={ia}");
                    assert_eq!(c.q, q, "stored bit changed: q={q} w={w} ia={ia}");
                }
            }
        }
    }

    #[test]
    fn skipping_gated_gnd_corrupts_and_burns_charge() {
        let mut c = BitCell::with_weight_bit(Corner::TT, true);
        c.q = true;
        let mut led = EnergyLedger::new();
        let params = PimParams { skip_gated_gnd: true, ..Default::default() };
        let out = c.pim_dot_product(true, &params, &mut led);
        assert!(!out.retained, "ablation must show the corruption mode");
        assert!(!c.q, "latch should have flipped");
        assert!(out.crowbar_charge > 0.0);
    }

    #[test]
    fn skip_without_activity_is_harmless() {
        // IA = 0 never asserts the wordline, so even with the footer on
        // there is no crowbar path.
        let mut c = BitCell::with_weight_bit(Corner::TT, true);
        c.q = true;
        let mut led = EnergyLedger::new();
        let params = PimParams { skip_gated_gnd: true, ..Default::default() };
        let out = c.pim_dot_product(false, &params, &mut led);
        assert!(out.retained);
        assert_eq!(out.crowbar_charge, 0.0);
    }

    #[test]
    fn latency_is_two_cycles() {
        assert!((BitCell::pim_latency() - 7.0e-9).abs() < 1e-15);
    }
}
