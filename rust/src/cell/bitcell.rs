//! 6T-2R bit-cell state and the small electrical solvers shared by the
//! operation models.

use crate::consts::VDD;
use crate::device::{CellVariation, Corner, Fet, FetKind, Rram, RramState};

/// Which half of the symmetric cell.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Side {
    /// The VDD1 / Q half.
    Left,
    /// The VDD2 / QB half.
    Right,
}

impl Side {
    /// Both sides, left first.
    pub const BOTH: [Side; 2] = [Side::Left, Side::Right];

    /// The opposite side.
    pub fn other(&self) -> Side {
        match self {
            Side::Left => Side::Right,
            Side::Right => Side::Left,
        }
    }
}

/// Relative pull-down width in the SRAM cell (pull-down : access :
/// pull-up = 1.5 : 1 : 0.8, the classic read-stability sizing).
pub const W_PULLDOWN: f64 = 1.5;
/// Relative access-transistor width.
pub const W_ACCESS: f64 = 1.0;
/// Relative pull-up width.
pub const W_PULLUP: f64 = 0.8;
/// The per-row gated-GND footer is shared by many cells and sized wide.
pub const W_GATED_GND: f64 = 8.0;

/// One 6T-2R bit-cell.
#[derive(Clone, Debug)]
pub struct BitCell {
    /// SRAM latch state: `true` ⇔ Q = 1 (and QB = 0).
    pub q: bool,
    /// RRAM on the VDD1 (left) power line.
    pub r_left: Rram,
    /// RRAM on the VDD2 (right) power line.
    pub r_right: Rram,
    /// Process corner of the cell's FETs.
    pub corner: Corner,
    /// Sampled Monte-Carlo mismatch.
    pub var: CellVariation,
}

impl BitCell {
    /// Fresh cell (Q = 0, both RRAMs HRS) at a corner.
    pub fn new(corner: Corner) -> BitCell {
        BitCell {
            q: false,
            r_left: Rram::new(),
            r_right: Rram::new(),
            corner,
            var: CellVariation::nominal(),
        }
    }

    /// Fresh cell with explicit Monte-Carlo mismatch.
    pub fn with_variation(corner: Corner, var: CellVariation) -> BitCell {
        let mut c = Self::new(corner);
        c.var = var;
        c
    }

    /// Both RRAMs forced to the same logical state (the paper programs
    /// R_LEFT and R_RIGHT identically to preserve cell symmetry, §III-A).
    pub fn with_weight_bit(corner: Corner, bit: bool) -> BitCell {
        let mut c = Self::new(corner);
        c.set_weight_bit(bit);
        c
    }

    /// Load a weight bit into both RRAMs without electrical programming.
    pub fn set_weight_bit(&mut self, bit: bool) {
        let s = if bit { RramState::Lrs } else { RramState::Hrs };
        self.r_left.force_state(s);
        self.r_right.force_state(s);
        self.apply_r_variation();
    }

    /// Apply the sampled MC resistance multipliers to both devices.
    pub fn apply_r_variation(&mut self) {
        let mult = |st: RramState, v: &CellVariation| match st {
            RramState::Lrs => v.r_lrs_mult,
            RramState::Hrs => v.r_hrs_mult,
        };
        self.r_left.r_mult = mult(self.r_left.state(), &self.var);
        self.r_right.r_mult = mult(self.r_right.state(), &self.var);
    }

    /// Stored weight bit (requires both devices consistent; debug-asserted).
    pub fn weight_bit(&self) -> bool {
        debug_assert_eq!(self.r_left.state(), self.r_right.state());
        self.r_left.state() == RramState::Lrs
    }

    /// The RRAM on `side`.
    pub fn rram(&self, side: Side) -> &Rram {
        match side {
            Side::Left => &self.r_left,
            Side::Right => &self.r_right,
        }
    }

    /// Mutable access to the RRAM on `side`.
    pub fn rram_mut(&mut self, side: Side) -> &mut Rram {
        match side {
            Side::Left => &mut self.r_left,
            Side::Right => &mut self.r_right,
        }
    }

    // ---- device instances (with this cell's corner + MC deltas) ----

    /// Access NMOS (M1/M6) with this cell's corner + mismatch.
    pub fn access_fet(&self) -> Fet {
        Fet::with_deltas(FetKind::Nmos, self.corner, W_ACCESS, self.var.vth_delta, self.var.beta_mult)
    }

    /// Pull-down NMOS (M3/M5).
    pub fn pulldown_fet(&self) -> Fet {
        Fet::with_deltas(FetKind::Nmos, self.corner, W_PULLDOWN, self.var.vth_delta, self.var.beta_mult)
    }

    /// Pull-up PMOS (M2/M4).
    pub fn pullup_fet(&self) -> Fet {
        Fet::with_deltas(FetKind::Pmos, self.corner, W_PULLUP, self.var.vth_delta, self.var.beta_mult)
    }

    /// Row-shared gated-GND footer NMOS.
    pub fn gated_gnd_fet(&self) -> Fet {
        // Row-shared footer: no per-cell mismatch (it is one physical device
        // per row; row-level variation is applied at the array layer).
        Fet::new(FetKind::Nmos, self.corner, W_GATED_GND)
    }

    /// Effective series resistance of the access + pull-up FET path used in
    /// programming / PIM current calculations (both near full gate drive).
    pub fn series_fet_resistance(&self, overdrive_gate: f64) -> f64 {
        let r_acc = self.access_fet().r_eff(overdrive_gate, 0.05);
        let r_pu = self.pullup_fet().r_eff(overdrive_gate, 0.05);
        r_acc + r_pu
    }

    /// Solve the self-consistent voltage across an RRAM in series with
    /// `r_fets` when `v_total` is applied across the chain. The RRAM's
    /// `sinh` I–V makes its effective resistance bias-dependent, so this is
    /// a damped fixed-point iteration.
    pub fn divider_v_rram(rram: &Rram, r_fets: f64, v_total: f64) -> f64 {
        let sign = v_total.signum();
        let vt = v_total.abs();
        if vt < 1e-9 {
            return 0.0;
        }
        let mut v_r = vt; // start assuming all voltage on the RRAM
        for _ in 0..60 {
            let r = rram.resistance(sign * v_r);
            let next = vt * r / (r + r_fets);
            v_r = 0.5 * v_r + 0.5 * next;
        }
        sign * v_r
    }

    /// Current through the PIM path of `side` during the sampling window
    /// (§III-C), given the powerline voltage `v_line` on that side's VDD
    /// rail and the input activation `ia` on that side's wordline.
    ///
    /// Cycle-1 (left): path exists iff Q = 1 (M2 on) and IA = 1 (M1 on);
    /// current flows BL(VDD) → M1 → Q → M2 → R_LEFT → VDD1(v_line).
    /// Cycle-2 (right) is symmetric with QB.
    pub fn pim_current(&self, side: Side, ia: bool, v_line: f64) -> f64 {
        let active = match side {
            Side::Left => self.q,
            Side::Right => !self.q,
        };
        let dev = self.rram(side);
        let drive = VDD - v_line;
        if drive <= 0.0 {
            return 0.0;
        }
        if !(active && ia) {
            // Inactive path: only subthreshold leakage through the stack.
            let leak = self.access_fet().id(0.0, drive);
            return leak.min(drive / dev.resistance(drive.max(0.05)));
        }
        let r_fets = self.series_fet_resistance(VDD);
        let v_r = Self::divider_v_rram(dev, r_fets, drive);
        dev.current(v_r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::consts::{R_HRS, R_LRS};

    #[test]
    fn weight_bit_roundtrip() {
        for bit in [false, true] {
            let c = BitCell::with_weight_bit(Corner::TT, bit);
            assert_eq!(c.weight_bit(), bit);
        }
    }

    #[test]
    fn divider_puts_most_voltage_on_hrs() {
        let c = BitCell::with_weight_bit(Corner::TT, false);
        let r_fets = c.series_fet_resistance(2.0);
        let v_r = BitCell::divider_v_rram(&c.r_left, r_fets, 2.0);
        assert!(v_r > 1.8, "HRS should take nearly all of the 2 V: {v_r}");
    }

    #[test]
    fn divider_sign_follows_polarity() {
        let c = BitCell::with_weight_bit(Corner::TT, true);
        let v = BitCell::divider_v_rram(&c.r_left, 5e3, -2.0);
        assert!(v < 0.0);
    }

    #[test]
    fn pim_current_truth_table() {
        // Fig. 5(c): the left side conducts a weight-dependent current only
        // when Q = 1 and IA = 1.
        let v_line = 0.3;
        for (q, ia, bit) in
            [(true, true, true), (true, true, false), (true, false, true), (false, true, true)]
        {
            let mut c = BitCell::with_weight_bit(Corner::TT, bit);
            c.q = q;
            let i = c.pim_current(Side::Left, ia, v_line);
            if q && ia {
                if bit {
                    // LRS: order-of-magnitude (VDD−v_line)/R_LRS.
                    let scale = (crate::consts::VDD - v_line) / R_LRS;
                    assert!(i > 0.5 * scale && i < 3.0 * scale, "LRS i = {i}");
                } else {
                    let scale = (crate::consts::VDD - v_line) / R_HRS;
                    assert!(i < 3.0 * scale, "HRS i = {i}");
                }
            } else {
                assert!(i < 1e-8, "inactive path leaks {i} A");
            }
        }
    }

    #[test]
    fn pim_right_side_mirrors_left() {
        let mut c = BitCell::with_weight_bit(Corner::TT, true);
        c.q = false; // QB = 1 → right side active
        let i_r = c.pim_current(Side::Right, true, 0.3);
        let i_l = c.pim_current(Side::Left, true, 0.3);
        assert!(i_r > 100.0 * i_l.max(1e-12));
    }

    #[test]
    fn lrs_hrs_current_ratio_large() {
        let mut on = BitCell::with_weight_bit(Corner::TT, true);
        let mut off = BitCell::with_weight_bit(Corner::TT, false);
        on.q = true;
        off.q = true;
        let ratio = on.pim_current(Side::Left, true, 0.3) / off.pim_current(Side::Left, true, 0.3);
        assert!(ratio > 20.0, "ON/OFF current ratio = {ratio}");
    }

    #[test]
    fn ff_corner_draws_more_current() {
        let mk = |corner| {
            let mut c = BitCell::with_weight_bit(corner, true);
            c.q = true;
            c.pim_current(Side::Left, true, 0.3)
        };
        assert!(mk(Corner::FF) > mk(Corner::TT));
        assert!(mk(Corner::TT) > mk(Corner::SS));
    }
}
