//! NVM programming (§III-A) and SRAM-mode operations (§III-B).
//!
//! Programming uses wordline overdrive (2 V) and drives the 2T-2R portion
//! of the cell; it is destructive to the SRAM data (the paper accepts this:
//! weights are programmed rarely relative to inference reads). SRAM
//! read/write/hold are identical to a conventional 6T cell; the RRAMs on
//! the power lines carry no DC current in hold, so retention is unaffected
//! by their state (Fig. 4).

use crate::consts::{T_PROGRAM, VDD, V_OVERDRIVE};
use crate::device::RramState;

use super::bitcell::{BitCell, Side};
use super::timing::{EnergyLedger, OpKind};

/// Outcome of a programming operation.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ProgramOutcome {
    /// Final state of the targeted device(s).
    pub state: RramState,
    /// Whether programming verified successfully.
    pub verified: bool,
    /// Number of 4 ns pulses applied.
    pub pulses: u32,
}

impl BitCell {
    /// Program one side's RRAM to LRS (§III-A first/second cycle).
    ///
    /// Left: WL1/WL2 overdriven to 2 V, BL = 2 V, BLB = 0 V, VDD1/VDD2 = 0,
    /// V1/V2 = 0. The complementary bitlines force QB → 0, turning M2 on and
    /// establishing the BL → M1 → Q → M2 → R_LEFT → VDD1 path. The internal
    /// node sits near BL, so the device sees positive (SET) polarity.
    /// Destroys SRAM data: the forced node values remain latched.
    pub fn program_lrs(&mut self, side: Side, ledger: &mut EnergyLedger) -> ProgramOutcome {
        // Voltage chain: BL(2 V) → access → pull-up → RRAM → VDD(0 V).
        let r_fets = self.series_fet_resistance(V_OVERDRIVE);
        let mut pulses = 0;
        // Up to 3 pulses with verify (real controllers pulse-verify; the
        // nominal device switches on the first pulse).
        for _ in 0..3 {
            let v_r = Self::divider_v_rram(self.rram(side), r_fets, V_OVERDRIVE);
            self.rram_mut(side).apply_voltage(v_r, T_PROGRAM);
            ledger.record(OpKind::ProgramPulse);
            pulses += 1;
            if self.rram(side).state() == RramState::Lrs {
                break;
            }
        }
        // Programming forces the storage nodes: for the left sequence the
        // bitlines drive Q = 1 / QB = 0 (BL = 2 V, BLB = 0 V); the right
        // sequence is complementary.
        self.q = side == Side::Left;
        self.apply_r_variation();
        let verified = self.verify_programmed(side, RramState::Lrs, ledger);
        ProgramOutcome { state: self.rram(side).state(), verified, pulses }
    }

    /// Program *both* RRAMs to HRS in a single cycle (§III-A).
    ///
    /// WL1/WL2 = 2 V, BL = BLB = 0 V, VDD1 = VDD2 = 2 V, V1/V2 = 0. Both
    /// storage nodes are forced to 0, both PMOS turn on, and current flows
    /// VDD → RRAM → PMOS → node → access → bitline, i.e. RESET polarity.
    pub fn program_hrs(&mut self, ledger: &mut EnergyLedger) -> ProgramOutcome {
        let r_fets = self.series_fet_resistance(V_OVERDRIVE);
        let mut pulses = 0;
        for _ in 0..3 {
            let mut done = true;
            for side in Side::BOTH {
                // Node is below the VDD rail ⇒ negative voltage across the
                // device in our polarity convention.
                let v_r = Self::divider_v_rram(self.rram(side), r_fets, -V_OVERDRIVE);
                self.rram_mut(side).apply_voltage(v_r, T_PROGRAM);
                done &= self.rram(side).state() == RramState::Hrs;
            }
            ledger.record(OpKind::ProgramPulse);
            pulses += 1;
            if done {
                break;
            }
        }
        // Both nodes were forced to 0 V; on release the latch resolves from
        // a symmetric condition — we model the deterministic post-layout
        // mismatch winner as Q = 0.
        self.q = false;
        self.apply_r_variation();
        let ok_l = self.verify_programmed(Side::Left, RramState::Hrs, ledger);
        let ok_r = self.verify_programmed(Side::Right, RramState::Hrs, ledger);
        ProgramOutcome {
            state: self.r_left.state(),
            verified: ok_l && ok_r,
            pulses,
        }
    }

    /// Program-verify read (§III-A): VDD1/VDD2 at VDD, wordlines at VDD,
    /// measure the bitline current for 1 ns; LRS ⇒ high current.
    pub fn verify_programmed(
        &self,
        side: Side,
        expect: RramState,
        ledger: &mut EnergyLedger,
    ) -> bool {
        let i = self.nvm_read_current(side, ledger);
        // Decision threshold at the geometric mean of the currents a
        // reference LRS/HRS device would produce through the *same* sense
        // path (the sinh I–V makes a linear-R estimate unusable here).
        let r_fets = self.series_fet_resistance(VDD);
        let i_ref = |st: RramState| {
            let d = crate::device::Rram::in_state(st);
            let v = Self::divider_v_rram(&d, r_fets, 0.45);
            d.current(v).abs()
        };
        let thresh = (i_ref(RramState::Lrs) * i_ref(RramState::Hrs)).sqrt();
        let read_state = if i > thresh { RramState::Lrs } else { RramState::Hrs };
        read_state == expect
    }

    /// NVM read current: bias the power line at VDD and sense on the
    /// bitline through the access path (1 ns window, §III-A).
    pub fn nvm_read_current(&self, side: Side, ledger: &mut EnergyLedger) -> f64 {
        ledger.record(OpKind::NvmRead);
        // Read chain: VDD(0.8) → RRAM → pull-up → node → access → BL(0 V
        // precharged low for current sensing). Use a 0.1 V effective read
        // drop across the device after the divider.
        let r_fets = self.series_fet_resistance(VDD);
        let dev = self.rram(side);
        let v_r = Self::divider_v_rram(dev, r_fets, 0.45);
        dev.current(v_r).abs()
    }

    // ---- SRAM mode (§III-B) ----

    /// SRAM write: identical to conventional 6T (bitlines driven
    /// complementary, wordlines asserted).
    pub fn sram_write(&mut self, bit: bool, ledger: &mut EnergyLedger) {
        ledger.record(OpKind::SramWrite);
        self.q = bit;
    }

    /// SRAM read: returns the stored bit and the differential bitline
    /// discharge current. The RRAM state must not affect the value (only
    /// slightly the timing/energy, captured in the ledger: §V-B reports
    /// 660 → 686 ps and 2.23 → 3.34 fJ per 512-bit row).
    pub fn sram_read(&self, ledger: &mut EnergyLedger) -> bool {
        ledger.record(OpKind::SramRead6t2r);
        self.q
    }

    /// Hold check (Fig. 4): with both wordlines low and nominal supplies,
    /// the latch holds regardless of RRAM state because the conducting
    /// pull-up carries no DC current (no drop across its RRAM) and the off
    /// pull-up blocks the other rail.
    pub fn hold_retains(&self) -> bool {
        // Static condition: the '1' node is connected to its VDD rail via a
        // conducting PMOS; current into the node is leakage-only, so the IR
        // drop across the RRAM is < 1 mV even in HRS.
        let leak = self.pulldown_fet().id(0.0, VDD);
        let worst_drop = leak * crate::consts::R_HRS;
        worst_drop < 0.05 * VDD
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::Corner;

    fn cell() -> (BitCell, EnergyLedger) {
        (BitCell::new(Corner::TT), EnergyLedger::new())
    }

    #[test]
    fn program_left_lrs_single_pulse() {
        let (mut c, mut led) = cell();
        let out = c.program_lrs(Side::Left, &mut led);
        assert_eq!(out.state, RramState::Lrs);
        assert!(out.verified);
        assert_eq!(out.pulses, 1, "nominal device should SET on first 4 ns pulse");
        // The right device is untouched (HRS) until its own cycle.
        assert_eq!(c.r_right.state(), RramState::Hrs);
        // Programming forced the latch: left sequence leaves Q = 1.
        assert!(c.q);
    }

    #[test]
    fn program_both_sides_lrs_two_cycles() {
        let (mut c, mut led) = cell();
        c.program_lrs(Side::Left, &mut led);
        c.program_lrs(Side::Right, &mut led);
        assert_eq!(c.r_left.state(), RramState::Lrs);
        assert_eq!(c.r_right.state(), RramState::Lrs);
        assert!(c.weight_bit());
    }

    #[test]
    fn program_hrs_resets_both_in_one_cycle() {
        let (mut c, mut led) = cell();
        c.program_lrs(Side::Left, &mut led);
        c.program_lrs(Side::Right, &mut led);
        let out = c.program_hrs(&mut led);
        assert_eq!(out.state, RramState::Hrs);
        assert!(out.verified);
        assert_eq!(out.pulses, 1);
        assert!(!c.weight_bit());
    }

    #[test]
    fn programming_is_destructive_to_sram_data() {
        let (mut c, mut led) = cell();
        c.sram_write(false, &mut led);
        c.program_lrs(Side::Left, &mut led);
        // §III-A: "programming is destructive to the SRAM data".
        assert!(c.q, "left LRS sequence forces Q = 1");
    }

    #[test]
    fn nvm_read_distinguishes_states() {
        let (mut c, mut led) = cell();
        c.set_weight_bit(true);
        let i_lrs = c.nvm_read_current(Side::Left, &mut led);
        c.set_weight_bit(false);
        let i_hrs = c.nvm_read_current(Side::Left, &mut led);
        assert!(i_lrs / i_hrs > 20.0, "read currents: {i_lrs} vs {i_hrs}");
    }

    #[test]
    fn sram_rw_independent_of_rram_state() {
        let (mut c, mut led) = cell();
        for weight in [false, true] {
            c.set_weight_bit(weight);
            for bit in [false, true] {
                c.sram_write(bit, &mut led);
                assert_eq!(c.sram_read(&mut led), bit);
                assert!(c.hold_retains());
            }
        }
    }

    #[test]
    fn verify_detects_failed_program() {
        // A device that refuses to switch (simulated by forcing HRS after a
        // "program") must fail verify.
        let (mut c, mut led) = cell();
        c.set_weight_bit(false);
        assert!(!c.verify_programmed(Side::Left, RramState::Lrs, &mut led));
        assert!(c.verify_programmed(Side::Left, RramState::Hrs, &mut led));
    }

    #[test]
    fn ledger_accumulates_programming_costs() {
        let (mut c, mut led) = cell();
        c.program_lrs(Side::Left, &mut led);
        assert!(led.total_energy() > 0.0);
        assert!(led.total_time() >= T_PROGRAM);
    }
}
