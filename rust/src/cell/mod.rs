//! The 6T-2R bit-cell (paper §III) — behavioral model.
//!
//! Transistor naming follows the paper's Fig. 2/3/5:
//!
//! ```text
//!             VDD1                VDD2
//!              │                   │
//!           [R_LEFT]            [R_RIGHT]        ← RRAMs on the power lines
//!              │                   │
//!        M2 ─┤ PMOS           M4 ─┤ PMOS         ← pull-ups (gates: QB / Q)
//!              │                   │
//!   BL ──M1──  Q ───cross────── QB ──M6── BLB    ← access NMOS (WL1 / WL2)
//!              │    coupled        │
//!        M3 ─┤ NMOS           M5 ─┤ NMOS         ← pull-downs (gates: QB / Q)
//!              │                   │
//!             V1-gated GND        V2-gated GND   ← shared per-row gated VSS
//! ```
//!
//! Sub-modules:
//! * [`bitcell`] — cell state + the resistive-divider electrical solver.
//! * [`ops`] — NVM programming (§III-A), SRAM hold/read/write (§III-B).
//! * [`pim`] — the two-cycle compute-on-powerline dot-product (§III-C),
//!   including the data-retention property and its violation when the
//!   gated-GND discipline is broken (ablation).
//! * [`snm`] — static noise margins via butterfly curves (Fig. 9b–d).
//! * [`timing`] — per-operation latency/energy ledger anchored to §V-B.

pub mod bitcell;
pub mod ops;
pub mod pim;
pub mod snm;
pub mod timing;

pub use bitcell::{BitCell, Side};
pub use pim::{PimCycleOutcome, PimParams};
pub use snm::{SnmKind, SnmResult};
pub use timing::{EnergyLedger, OpKind};
