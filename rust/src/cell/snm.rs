//! Static noise margins (Fig. 9b–d): butterfly curves for hold/read and a
//! combined read/write butterfly for the write margin, comparing the
//! conventional 6T cell against the proposed 6T-2R cell.
//!
//! The 6T-2R differences captured here:
//! * each inverter's pull-up reaches VDD through its RRAM (series R on the
//!   supply) — irrelevant at DC in hold (no current) but visible whenever
//!   the pull-up carries current (read bump recovery, write flip);
//! * each inverter's pull-down reaches GND through the row-shared gated-GND
//!   footer (small series R).

use crate::consts::VDD;
use crate::device::{Corner, Fet, FetKind, Rram, RramState};

use super::bitcell::{W_ACCESS, W_GATED_GND, W_PULLDOWN, W_PULLUP};

/// Which margin.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SnmKind {
    /// Hold margin (wordlines low).
    Hold,
    /// Read margin (access on, bitlines precharged).
    Read,
    /// Write margin (one bitline driven low).
    Write,
}

impl SnmKind {
    /// Lower-case label for CSV emission.
    pub fn name(&self) -> &'static str {
        match self {
            SnmKind::Hold => "hold",
            SnmKind::Read => "read",
            SnmKind::Write => "write",
        }
    }
}

/// Cell flavor for the comparison.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CellFlavor {
    /// Conventional 6T (no RRAM, no gated-GND footer).
    Conventional6t,
    /// Proposed 6T-2R with both RRAMs in the given state.
    SixT2r(RramState),
}

/// SNM analysis result.
#[derive(Clone, Debug)]
pub struct SnmResult {
    /// Which margin was computed.
    pub kind: SnmKind,
    /// Cell flavor analyzed.
    pub flavor: CellFlavor,
    /// Process corner.
    pub corner: Corner,
    /// Margin in volts (side of the largest embedded square).
    pub snm: f64,
    /// First voltage-transfer curve (vin, vout) — one butterfly wing —
    /// for figure emission.
    pub vtc_a: Vec<(f64, f64)>,
    /// Second voltage-transfer curve (mirrored by the plotter).
    pub vtc_b: Vec<(f64, f64)>,
}

/// Number of VTC sample points.
const N_PTS: usize = 161;

/// Build the inverter transfer curve for one half-cell under the given
/// operating condition.
///
/// `read_access`: access transistor on with its bitline precharged to VDD
/// (read condition — pulls the output up).
/// `write_access`: access transistor on with its bitline at 0 V (write
/// condition — pulls the output down).
fn half_cell_vtc(
    flavor: CellFlavor,
    corner: Corner,
    read_access: bool,
    write_access: bool,
) -> Vec<(f64, f64)> {
    let nmos = Fet::new(FetKind::Nmos, corner, W_PULLDOWN);
    let pmos = Fet::new(FetKind::Pmos, corner, W_PULLUP);
    let access = Fet::new(FetKind::Nmos, corner, W_ACCESS);

    let (r_up, r_dn) = match flavor {
        CellFlavor::Conventional6t => (0.0, 0.0),
        CellFlavor::SixT2r(state) => {
            let r = Rram::in_state(state).read_resistance();
            // Gated-GND footer: wide shared device, a few hundred ohms.
            let footer = Fet::new(FetKind::Nmos, corner, W_GATED_GND);
            (r, footer.r_eff(VDD, 0.02))
        }
    };

    (0..N_PTS)
        .map(|i| {
            let vin = VDD * i as f64 / (N_PTS - 1) as f64;
            let vout = solve_output(
                &nmos, &pmos, &access, vin, r_up, r_dn, read_access, write_access,
            );
            (vin, vout)
        })
        .collect()
}

/// Solve the output node by balancing pull-up, pull-down and access-path
/// currents with bisection on Vout.
fn solve_output(
    nmos: &Fet,
    pmos: &Fet,
    access: &Fet,
    vin: f64,
    r_up: f64,
    r_dn: f64,
    read_access: bool,
    write_access: bool,
) -> f64 {
    // Net current INTO the node as a function of vout; monotonically
    // decreasing in vout.
    let f = |vout: f64| -> f64 {
        // Pull-up through series RRAM: iterate the IR drop.
        let mut i_up = pmos.id(VDD - vin, (VDD - vout).max(0.0));
        if r_up > 1e-3 {
            for _ in 0..20 {
                let vnode = (VDD - i_up * r_up).max(vout);
                i_up = 0.5 * i_up + 0.5 * pmos.id(vnode - vin, (vnode - vout).max(0.0));
            }
        }
        // Pull-down through the footer: source degeneration.
        let mut i_dn = nmos.id(vin, vout);
        if r_dn > 1e-3 {
            for _ in 0..20 {
                let vs = (i_dn * r_dn).min(vout);
                i_dn = 0.5 * i_dn + 0.5 * nmos.id(vin - vs, (vout - vs).max(0.0));
            }
        }
        // Access transistor contributions.
        let mut i_acc = 0.0;
        if read_access {
            // BL at VDD, gate at VDD: NMOS source is the lower of the two
            // terminals — current flows into the node while vout < VDD.
            i_acc += access.id(VDD - vout, (VDD - vout).max(0.0));
        }
        if write_access {
            // BL at 0 V: current flows out of the node.
            i_acc -= access.id(VDD, vout);
        }
        i_up - i_dn + i_acc
    };
    bisect_decreasing(f, 0.0, VDD)
}

fn bisect_decreasing<F: Fn(f64) -> f64>(f: F, lo0: f64, hi0: f64) -> f64 {
    let (mut lo, mut hi) = (lo0, hi0);
    if f(lo) <= 0.0 {
        return lo;
    }
    if f(hi) >= 0.0 {
        return hi;
    }
    for _ in 0..60 {
        let mid = 0.5 * (lo + hi);
        if f(mid) > 0.0 {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    0.5 * (lo + hi)
}

/// Largest square that fits between curve A (as given) and the *mirror* of
/// curve B, inside one butterfly lobe — the standard graphical SNM metric
/// (Seevinck). Both curves are (vin, vout) samples; curve B is mirrored by
/// swapping axes. Returns the max over both lobes' squares... for the hold /
/// read butterflies; for the write margin the caller uses the single-lobe
/// variant [`largest_square`] directly with `minimize = false`.
fn butterfly_snm(vtc_a: &[(f64, f64)], vtc_b: &[(f64, f64)]) -> f64 {
    // Lobe 1: A above mirrored-B; Lobe 2: the symmetric one (swap roles).
    let l1 = largest_square(vtc_a, vtc_b);
    let l2 = largest_square(vtc_b, vtc_a);
    l1.min(l2)
}

/// Side of the largest square fitting between `upper` (a VTC, vin→vout) and
/// the mirror of `lower` (vout→vin). Diagonal search along u = (vin−vout)/√2.
fn largest_square(upper: &[(f64, f64)], lower: &[(f64, f64)]) -> f64 {
    // Mirror of `lower`: the curve (vout, vin). For a square of side s
    // anchored at (x, y) with y = f_upper(x): we need the mirrored curve to
    // pass below/right such that (x+s, y-s)… the classic formulation:
    // SNM = max over x of the largest square between y_upper(x) and
    // x_lower(y). Practical approach: for each point (x, yu) on `upper`,
    // find the mirrored-curve value ym(x') and maximize min-gap along the
    // -45° diagonal.
    let mirror: Vec<(f64, f64)> = lower.iter().map(|&(vi, vo)| (vo, vi)).collect();
    let interp = |curve: &[(f64, f64)], x: f64| -> f64 {
        // Curves may be non-monotonic in x after mirroring; use nearest
        // segment interpolation over the sorted-by-x view.
        let mut best = f64::MAX;
        let mut val = 0.0;
        for w in curve.windows(2) {
            let (x0, y0) = w[0];
            let (x1, y1) = w[1];
            if (x - x0) * (x - x1) <= 0.0 && (x1 - x0).abs() > 1e-12 {
                let t = (x - x0) / (x1 - x0);
                return y0 + t * (y1 - y0);
            }
            let d = (x - x0).abs();
            if d < best {
                best = d;
                val = y0;
            }
        }
        val
    };
    // For each diagonal offset c, the square side is determined by the
    // vertical gap between upper(x) and mirror(x) measured along the
    // diagonal; SNM is the max over anchor positions of min(gap)/... We use
    // the standard diagonal-line method: slide a -45° line, the SNM is the
    // maximum over lobes of (max diagonal separation)/√2.
    let mut best = 0.0f64;
    for i in 0..=200 {
        let x = VDD * i as f64 / 200.0;
        let yu = interp(upper, x);
        let ym = interp(&mirror, x);
        if yu > ym {
            // Diagonal separation between the curves at this x maps to a
            // square of side gap/(1+1) via the 45° geometry.
            let gap = yu - ym;
            best = best.max(gap / 2.0);
        }
    }
    best
}

/// Compute an SNM figure for a given kind/flavor/corner.
pub fn snm(kind: SnmKind, flavor: CellFlavor, corner: Corner) -> SnmResult {
    let (vtc_a, vtc_b, margin) = match kind {
        SnmKind::Hold => {
            let a = half_cell_vtc(flavor, corner, false, false);
            let b = half_cell_vtc(flavor, corner, false, false);
            let m = butterfly_snm(&a, &b);
            (a, b, m)
        }
        SnmKind::Read => {
            let a = half_cell_vtc(flavor, corner, true, false);
            let b = half_cell_vtc(flavor, corner, true, false);
            let m = butterfly_snm(&a, &b);
            (a, b, m)
        }
        SnmKind::Write => {
            // Combined butterfly: one half in read condition, the other in
            // write condition (BL = 0). A positive margin (single open lobe)
            // means the cell is writable; the margin is the square in the
            // remaining lobe.
            let a = half_cell_vtc(flavor, corner, true, false);
            let b = half_cell_vtc(flavor, corner, false, true);
            let m = largest_square(&a, &b);
            (a, b, m)
        }
    };
    SnmResult { kind, flavor, corner, snm: margin, vtc_a, vtc_b }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(kind: SnmKind, flavor: CellFlavor) -> f64 {
        snm(kind, flavor, Corner::TT).snm
    }

    #[test]
    fn hold_snm_plausible_magnitude() {
        let h6 = m(SnmKind::Hold, CellFlavor::Conventional6t);
        // Typical hold SNM ≈ 0.3–0.45·VDD for a balanced cell at 0.8 V.
        assert!(h6 > 0.15 && h6 < 0.45, "hold SNM = {h6}");
    }

    #[test]
    fn hold_unaffected_by_rram_state() {
        // Fig. 9(b): hold butterfly of 6T-2R ≈ 6T. With LRS (the
        // weight-programmed state used during PIM campaigns) the 25 kΩ
        // series drop at the µA-level crossover current is a few mV —
        // negligible. With HRS the DC transfer region does starve (1.2 MΩ
        // supply), which our static model reports as a reduced but still
        // robustly bistable margin; see EXPERIMENTS.md E2 for discussion.
        let h6 = m(SnmKind::Hold, CellFlavor::Conventional6t);
        let h_lrs = m(SnmKind::Hold, CellFlavor::SixT2r(RramState::Lrs));
        assert!((h_lrs - h6).abs() / h6 < 0.10, "hold 6T={h6} 6T2R(LRS)={h_lrs}");
        let h_hrs = m(SnmKind::Hold, CellFlavor::SixT2r(RramState::Hrs));
        assert!(h_hrs > 0.08, "HRS hold must stay bistable: {h_hrs}");
    }

    #[test]
    fn read_snm_smaller_than_hold() {
        let h = m(SnmKind::Hold, CellFlavor::Conventional6t);
        let r = m(SnmKind::Read, CellFlavor::Conventional6t);
        assert!(r < h, "read {r} !< hold {h}");
        assert!(r > 0.02, "cell must still be read-stable: {r}");
    }

    #[test]
    fn read_snm_slightly_degraded_in_6t2r() {
        // Fig. 9(c): "slight reduction in SNM compared to the 6T SRAM, due
        // to the additional series resistance".
        let r6 = m(SnmKind::Read, CellFlavor::Conventional6t);
        let r2 = m(SnmKind::Read, CellFlavor::SixT2r(RramState::Lrs));
        assert!(r2 <= r6 * 1.001, "6T2R read {r2} vs 6T {r6}");
        assert!(r2 > r6 * 0.75, "degradation should be minor: {r2} vs {r6}");
    }

    #[test]
    fn write_margin_positive_and_reduced_in_6t2r() {
        let w6 = m(SnmKind::Write, CellFlavor::Conventional6t);
        let w2 = m(SnmKind::Write, CellFlavor::SixT2r(RramState::Lrs));
        assert!(w6 > 0.0, "6T must be writable");
        assert!(w2 > 0.0, "6T-2R must be writable");
        assert!(w2 <= w6 * 1.001, "write margin 6T2R {w2} vs 6T {w6}");
    }

    #[test]
    fn corners_order_read_snm() {
        // Weaker NMOS (SS) lowers the read bump slower... the key check is
        // just that all corners yield positive, finite margins.
        for c in Corner::ALL {
            let r = snm(SnmKind::Read, CellFlavor::SixT2r(RramState::Lrs), c).snm;
            assert!(r > 0.0 && r < VDD, "{c:?} read SNM = {r}");
        }
    }

    #[test]
    fn vtc_monotone_decreasing() {
        let res = snm(SnmKind::Hold, CellFlavor::Conventional6t, Corner::TT);
        for w in res.vtc_a.windows(2) {
            assert!(w[1].1 <= w[0].1 + 1e-9, "VTC must be non-increasing");
        }
    }
}
