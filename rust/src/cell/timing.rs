//! Per-operation latency/energy ledger, anchored to the paper's §V-B/§V-D
//! numbers.
//!
//! Anchors (paper):
//! * read latency 660 ps (6T) → 686 ps (6T-2R); 512-bit row read energy
//!   2.23 fJ → 3.34 fJ (§V-B);
//! * 4 ns programming pulses (§III-A);
//! * 3.5 ns PIM cycles (§III-C);
//! * 160 ns per 6-bit SAR conversion at 50 MHz (§V-D);
//! * full-array 4b×4b MAC: 1280 ns, ≈1.07 nJ → 25.6 GOPS, 30.73 TOPS/W,
//!   with the array ≈60 % of energy, ADC next, then WCC (§V-D).
//!
//! The per-op energies below are derived from those totals (see
//! EXPERIMENTS.md E8 for the arithmetic) so that summing the ledger over
//! the paper's workload reproduces the paper's throughput/efficiency row.

/// Operation kinds tracked by the ledger.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OpKind {
    /// One 4 ns NVM programming pulse (one cell).
    ProgramPulse,
    /// One 1 ns NVM verify/read (one cell).
    NvmRead,
    /// Conventional-6T 512-bit row read (baseline comparison).
    SramRead6t,
    /// 6T-2R 512-bit row read.
    SramRead6t2r,
    /// 512-bit row write (6T-2R; write path unchanged vs 6T).
    SramWrite,
    /// One 3.5 ns PIM cycle over a whole 128×512 sub-array (one side).
    PimArrayCycle,
    /// One 6-bit SAR conversion (one word-column ADC).
    AdcConversion,
    /// One WCC weighted-sampling event (one word, one side, one bit-plane).
    WccSample,
    /// Digital post-processing per word result (shift-add/subtract).
    DigitalPostOp,
    /// Cache line transfer for the flush/reload ablation (64 B line).
    CacheLineMove,
}

impl OpKind {
    /// Every op kind, in ledger index order.
    pub const ALL: [OpKind; 10] = [
        OpKind::ProgramPulse,
        OpKind::NvmRead,
        OpKind::SramRead6t,
        OpKind::SramRead6t2r,
        OpKind::SramWrite,
        OpKind::PimArrayCycle,
        OpKind::AdcConversion,
        OpKind::WccSample,
        OpKind::DigitalPostOp,
        OpKind::CacheLineMove,
    ];

    /// (latency seconds, energy joules) per event.
    pub fn cost(&self) -> (f64, f64) {
        use crate::consts::*;
        match self {
            // 2 V × ~57 µA × 4 ns ≈ 0.46 pJ per cell programming pulse.
            OpKind::ProgramPulse => (T_PROGRAM, 0.46e-12),
            // 1 ns verify read at ~18 µA, 0.8 V.
            OpKind::NvmRead => (1.0e-9, 14.4e-15),
            OpKind::SramRead6t => (T_READ_6T, E_READ_ROW_6T),
            OpKind::SramRead6t2r => (T_READ_6T2R, E_READ_ROW_6T2R),
            // Write path is the conventional one; slightly higher energy
            // than a read due to full bitline swing.
            OpKind::SramWrite => (T_READ_6T2R, 4.2e-15),
            // Derived: array ≈60 % of the 1.07 nJ full-MAC energy over
            // 8 side×bit-plane steps ⇒ 80 pJ per array sampling cycle.
            OpKind::PimArrayCycle => (T_PIM_CYCLE, 80.0e-12),
            // Derived: ADC share ≈30 % over 1024 conversions ⇒ ~312 fJ.
            OpKind::AdcConversion => (T_ADC_CONVERSION, 312.5e-15),
            // Derived: WCC share ≈10 % over 8 steps × 128 words.
            OpKind::WccSample => (T_PIM_SAMPLE, 104.0e-15),
            // Shift-add/subtract in the digital periphery, per word.
            OpKind::DigitalPostOp => (0.5e-9, 5.0e-15),
            // 64 B line move between cache levels (flush/reload ablation):
            // representative LLC slice access (≈2 ns, ≈20 pJ).
            OpKind::CacheLineMove => (2.0e-9, 20.0e-12),
        }
    }

    /// Snake-case label for breakdown reports.
    pub fn name(&self) -> &'static str {
        match self {
            OpKind::ProgramPulse => "program_pulse",
            OpKind::NvmRead => "nvm_read",
            OpKind::SramRead6t => "sram_read_6t",
            OpKind::SramRead6t2r => "sram_read_6t2r",
            OpKind::SramWrite => "sram_write",
            OpKind::PimArrayCycle => "pim_array_cycle",
            OpKind::AdcConversion => "adc_conversion",
            OpKind::WccSample => "wcc_sample",
            OpKind::DigitalPostOp => "digital_post_op",
            OpKind::CacheLineMove => "cache_line_move",
        }
    }
}

/// Accumulating latency/energy ledger.
///
/// Latency is accumulated *serially* (sum of op latencies); parallelism is
/// the scheduler's concern — [`crate::perf`] computes pipelined wall-clock
/// from op counts, and the coordinator tracks real elapsed time.
#[derive(Clone, Debug, Default)]
pub struct EnergyLedger {
    counts: [u64; OpKind::ALL.len()],
}

impl EnergyLedger {
    /// Empty ledger.
    pub fn new() -> Self {
        Self::default()
    }

    fn idx(kind: OpKind) -> usize {
        OpKind::ALL.iter().position(|k| *k == kind).unwrap()
    }

    /// Record one event of `kind`.
    pub fn record(&mut self, kind: OpKind) {
        self.record_n(kind, 1);
    }

    /// Record `n` events of `kind`.
    pub fn record_n(&mut self, kind: OpKind, n: u64) {
        self.counts[Self::idx(kind)] += n;
    }

    /// Event count for `kind`.
    pub fn count(&self, kind: OpKind) -> u64 {
        self.counts[Self::idx(kind)]
    }

    /// Total serial latency (s).
    pub fn total_time(&self) -> f64 {
        OpKind::ALL
            .iter()
            .map(|k| self.count(*k) as f64 * k.cost().0)
            .sum()
    }

    /// Total energy (J).
    pub fn total_energy(&self) -> f64 {
        OpKind::ALL
            .iter()
            .map(|k| self.count(*k) as f64 * k.cost().1)
            .sum()
    }

    /// Energy broken down per op kind, as (name, joules, fraction).
    pub fn energy_breakdown(&self) -> Vec<(&'static str, f64, f64)> {
        let total = self.total_energy().max(1e-300);
        OpKind::ALL
            .iter()
            .filter(|k| self.count(**k) > 0)
            .map(|k| {
                let e = self.count(*k) as f64 * k.cost().1;
                (k.name(), e, e / total)
            })
            .collect()
    }

    /// Merge another ledger into this one.
    pub fn merge(&mut self, other: &EnergyLedger) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
    }

    /// Clear all counts.
    pub fn reset(&mut self) {
        self.counts = Default::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::consts::*;

    #[test]
    fn read_anchors_match_paper() {
        assert_eq!(OpKind::SramRead6t.cost(), (T_READ_6T, E_READ_ROW_6T));
        assert_eq!(OpKind::SramRead6t2r.cost(), (T_READ_6T2R, E_READ_ROW_6T2R));
    }

    #[test]
    fn ledger_accumulates_and_merges() {
        let mut a = EnergyLedger::new();
        a.record_n(OpKind::AdcConversion, 10);
        let mut b = EnergyLedger::new();
        b.record(OpKind::AdcConversion);
        b.record(OpKind::ProgramPulse);
        a.merge(&b);
        assert_eq!(a.count(OpKind::AdcConversion), 11);
        assert_eq!(a.count(OpKind::ProgramPulse), 1);
        let t = 11.0 * T_ADC_CONVERSION + T_PROGRAM;
        assert!((a.total_time() - t).abs() < 1e-18);
    }

    #[test]
    fn full_array_mac_reproduces_paper_energy_and_power() {
        // One complete 4b×4b MAC over the 128×512 sub-array:
        // 2 sides × 4 bit-planes = 8 steps; per step one array cycle,
        // 128 WCC samples, 128 ADC conversions; + digital post ops.
        let mut led = EnergyLedger::new();
        led.record_n(OpKind::PimArrayCycle, 8);
        led.record_n(OpKind::WccSample, 8 * 128);
        led.record_n(OpKind::AdcConversion, 8 * 128);
        let e = led.total_energy();
        // Paper §V-D: 25.6 GOPS at 30.73 TOPS/W ⇒ 0.833 mW ⇒ 1.066 nJ per
        // 1280 ns full-array MAC.
        assert!((e - 1.066e-9).abs() / 1.066e-9 < 0.05, "E = {e}");
        // Array share ≈ 60 %.
        let array_frac = led
            .energy_breakdown()
            .iter()
            .find(|(n, _, _)| *n == "pim_array_cycle")
            .unwrap()
            .2;
        assert!((array_frac - 0.60).abs() < 0.05, "array share = {array_frac}");
        // Wall-clock is ADC-bound: 8 × 160 ns = 1280 ns (pipelined view in
        // perf/, not the serial ledger sum).
        let t_pipe = 8.0 * T_ADC_CONVERSION;
        // 128 rows × 128 word-columns = 16384 MACs × 2 ops; each row
        // contributes on exactly one side (left if Q=1, right if Q=0), so
        // the two cycles together complete ONE full-array MAC.
        let ops = 128.0 * 128.0 * 2.0;
        let gops = ops / t_pipe / 1e9;
        assert!((gops - 25.6).abs() < 0.1, "GOPS = {gops}");
        let tops_w = ops / t_pipe / (e / t_pipe) / 1e12;
        assert!((tops_w - 30.73).abs() < 2.0, "TOPS/W = {tops_w}");
    }

    #[test]
    fn breakdown_fractions_sum_to_one() {
        let mut led = EnergyLedger::new();
        led.record_n(OpKind::ProgramPulse, 3);
        led.record_n(OpKind::NvmRead, 5);
        let total: f64 = led.energy_breakdown().iter().map(|(_, _, f)| f).sum();
        assert!((total - 1.0).abs() < 1e-12);
    }
}
