//! Sample-and-hold stage (Fig. 6d, left block).
//!
//! Converts the WCC's weighted current to a held voltage on the sampling
//! capacitor: `V = V0 − R_ti · I`, with capacitor droop during the
//! conversion window and kT/C + switch noise. Fig. 10(b) demonstrates the
//! S&H "does not contribute any non-linearity" — it is linear by
//! construction here; droop and noise are small additive terms.

use crate::device::VariationModel;
use crate::pim::transfer::{TransferModel, V_SAMP_MAX};
use crate::util::rng::Pcg64;

/// Sample-and-hold instance.
#[derive(Clone, Copy, Debug)]
pub struct SampleHold {
    /// Transimpedance (V/A), trimmed at TT — from the transfer model.
    pub r_ti: f64,
    /// Hold droop rate (V/s) — leakage off the sampling cap.
    pub droop_rate: f64,
    /// RMS sampling noise (V), from the variation model.
    pub sigma_v: f64,
}

impl SampleHold {
    /// S&H stage trimmed against a transfer model, with noise from `var`.
    pub fn new(transfer: &TransferModel, var: &VariationModel) -> SampleHold {
        SampleHold {
            r_ti: transfer.r_ti,
            // ~40 µV droop over a 160 ns conversion: negligible vs the
            // 8.9 mV LSB, matching the paper's "no non-linearity" claim.
            droop_rate: 250.0,
            sigma_v: var.sigma_sh,
        }
    }

    /// Ideal (noiseless, droopless) sampled voltage.
    pub fn sample_ideal(&self, current: f64) -> f64 {
        V_SAMP_MAX - self.r_ti * current
    }

    /// Sampled voltage after holding for `t_hold` seconds, with one noise
    /// realization drawn from `rng` (None ⇒ noiseless).
    pub fn sample(&self, current: f64, t_hold: f64, rng: Option<&mut Pcg64>) -> f64 {
        let mut v = self.sample_ideal(current) - self.droop_rate * t_hold;
        if let Some(r) = rng {
            v += r.normal(0.0, self.sigma_v);
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::consts::T_ADC_CONVERSION;
    use crate::device::VariationModel;

    fn sh() -> SampleHold {
        SampleHold::new(&TransferModel::tt(), &VariationModel::default())
    }

    #[test]
    fn linear_in_current() {
        // Fig. 10(b): the S&H adds no nonlinearity.
        let s = sh();
        let i1 = 1.0e-3;
        let i2 = 2.0e-3;
        let v0 = s.sample_ideal(0.0);
        let d1 = v0 - s.sample_ideal(i1);
        let d2 = v0 - s.sample_ideal(i2);
        assert!((d2 / d1 - 2.0).abs() < 1e-12);
    }

    #[test]
    fn droop_below_lsb() {
        let s = sh();
        let droop = s.sample_ideal(1e-3) - s.sample(1e-3, T_ADC_CONVERSION, None);
        assert!(droop > 0.0);
        assert!(droop < 0.0089 / 8.0, "droop {droop} V must be ≪ LSB");
    }

    #[test]
    fn noise_has_configured_sigma() {
        let s = sh();
        let mut rng = Pcg64::seeded(3);
        let n = 20_000;
        let base = s.sample_ideal(1e-3);
        let vs: Vec<f64> = (0..n).map(|_| s.sample(1e-3, 0.0, Some(&mut rng)) - base).collect();
        let mean = vs.iter().sum::<f64>() / n as f64;
        let std = (vs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64).sqrt();
        assert!((std - s.sigma_v).abs() / s.sigma_v < 0.05);
    }
}
