//! The shared control FSM (Fig. 6c, "controlled by a shared finite-state
//! machine") that sequences PIM operations on a sub-array.
//!
//! Per §III-C, each PIM cycle on one side decomposes into:
//!   Settle (1.5 ns)  — active VDD line pulled to the WCC reference,
//!                      gated-GND still on, wordlines low;
//!   Sample (1.0 ns)  — IA on the wordline, V1/V2 off, current sampled;
//!   Restore (1.0 ns) — supplies and footers back to nominal.
//! A 6-bit SAR conversion (160 ns) of the held sample runs after the
//! analog cycle; with bit-serial 4-bit inputs the per-side latency is
//! 4 × 160 ns = 640 ns (§V-D — ADC-dominated).

use crate::cell::timing::{EnergyLedger, OpKind};
use crate::consts::{T_ADC_CONVERSION, T_PIM_RESTORE, T_PIM_SAMPLE, T_PIM_SETTLE};

/// FSM states for one PIM side-cycle.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PimPhase {
    /// No PIM activity (SRAM mode).
    Idle,
    /// Active VDD line pulled to the WCC reference (1.5 ns).
    Settle,
    /// IA applied, current sampled (1 ns).
    Sample,
    /// Supplies restored to nominal (1 ns).
    Restore,
    /// SAR conversion of the held sample (160 ns).
    Convert,
}

impl PimPhase {
    /// Phase duration (s), per §III-C / §V-D.
    pub fn duration(&self) -> f64 {
        match self {
            PimPhase::Idle => 0.0,
            PimPhase::Settle => T_PIM_SETTLE,
            PimPhase::Sample => T_PIM_SAMPLE,
            PimPhase::Restore => T_PIM_RESTORE,
            PimPhase::Convert => T_ADC_CONVERSION,
        }
    }
}

/// Control-signal snapshot for the active side during a phase (§III-C's
/// timing diagram, encoded): wordline enable, gated-GND on, line at V_REF.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Controls {
    /// Wordline asserted (IA applied).
    pub wl_active: bool,
    /// Gated-GND footer conducting.
    pub gated_gnd_on: bool,
    /// Active power line held at the WCC reference.
    pub line_at_vref: bool,
}

/// One sub-array's PIM sequencer.
#[derive(Clone, Debug)]
pub struct PimFsm {
    /// Current phase.
    pub phase: PimPhase,
    /// Elapsed time in the current side-cycle (s).
    pub t: f64,
    /// Trace of (phase, duration) for inspection/tests.
    pub trace: Vec<(PimPhase, f64)>,
}

impl PimFsm {
    /// Idle sequencer.
    pub fn new() -> PimFsm {
        PimFsm { phase: PimPhase::Idle, t: 0.0, trace: Vec::new() }
    }

    /// Control signals implied by a phase — the discipline that preserves
    /// the stored data (Sample: WL on, footer OFF — never both on).
    pub fn controls(phase: PimPhase) -> Controls {
        match phase {
            PimPhase::Idle => Controls { wl_active: false, gated_gnd_on: true, line_at_vref: false },
            PimPhase::Settle => Controls { wl_active: false, gated_gnd_on: true, line_at_vref: true },
            PimPhase::Sample => Controls { wl_active: true, gated_gnd_on: false, line_at_vref: true },
            PimPhase::Restore => Controls { wl_active: false, gated_gnd_on: false, line_at_vref: false },
            PimPhase::Convert => Controls { wl_active: false, gated_gnd_on: true, line_at_vref: false },
        }
    }

    fn advance(&mut self, phase: PimPhase) {
        self.trace.push((phase, phase.duration()));
        self.t += phase.duration();
        self.phase = phase;
    }

    /// Run one full side-cycle (settle→sample→restore→convert), recording
    /// array + conversion costs for `n_words` word columns.
    pub fn run_side_cycle(&mut self, n_words: usize, ledger: &mut EnergyLedger) -> f64 {
        self.t = 0.0;
        self.advance(PimPhase::Settle);
        self.advance(PimPhase::Sample);
        self.advance(PimPhase::Restore);
        ledger.record(OpKind::PimArrayCycle);
        ledger.record_n(OpKind::WccSample, n_words as u64);
        self.advance(PimPhase::Convert);
        ledger.record_n(OpKind::AdcConversion, n_words as u64);
        self.advance(PimPhase::Idle);
        self.t
    }

    /// Wall-clock for a full multi-bit MAC: `act_bits` bit-planes × 2 sides,
    /// ADC-dominated (analog cycle overlaps the next conversion setup).
    pub fn full_mac_latency(act_bits: u32) -> f64 {
        2.0 * act_bits as f64 * T_ADC_CONVERSION
    }
}

impl Default for PimFsm {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn safety_invariant_wl_xor_footer() {
        // The retention discipline: the wordline and the gated-GND footer
        // are never simultaneously on in any phase — this is precisely what
        // prevents both the crowbar path and the cycle-2 data flip.
        for phase in [PimPhase::Idle, PimPhase::Settle, PimPhase::Sample, PimPhase::Restore, PimPhase::Convert] {
            let c = PimFsm::controls(phase);
            assert!(!(c.wl_active && c.gated_gnd_on), "{phase:?} violates the discipline");
        }
    }

    #[test]
    fn side_cycle_duration() {
        let mut fsm = PimFsm::new();
        let mut led = EnergyLedger::new();
        let t = fsm.run_side_cycle(128, &mut led);
        // 3.5 ns analog + 160 ns conversion.
        assert!((t - 163.5e-9).abs() < 1e-15, "t = {t}");
        assert_eq!(led.count(OpKind::AdcConversion), 128);
        assert_eq!(led.count(OpKind::PimArrayCycle), 1);
    }

    #[test]
    fn full_mac_latency_matches_paper() {
        // §V-D: 640 ns per side for 4-bit inputs ⇒ 1280 ns both sides.
        assert!((PimFsm::full_mac_latency(4) - 1280.0e-9).abs() < 1e-15);
    }

    #[test]
    fn trace_records_phases_in_order() {
        let mut fsm = PimFsm::new();
        let mut led = EnergyLedger::new();
        fsm.run_side_cycle(4, &mut led);
        let phases: Vec<PimPhase> = fsm.trace.iter().map(|(p, _)| *p).collect();
        assert_eq!(
            phases,
            vec![PimPhase::Settle, PimPhase::Sample, PimPhase::Restore, PimPhase::Convert, PimPhase::Idle]
        );
    }
}
