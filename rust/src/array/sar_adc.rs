//! Behavioral 6-bit SAR ADC (Fig. 6d).
//!
//! Strong-arm-latch comparator + 6-bit capacitive DAC + SAR logic running
//! the binary search at 50 MHz (8 cycles ⇒ 160 ns per conversion, §V-D).
//! Supports the calibrated (V_REFP = 660 mV / V_REFN = 90 mV) and
//! uncalibrated (V_REF = 800 mV) reference configurations of Fig. 12, a
//! comparator input-referred offset, and per-decision noise.

use crate::consts::{ADC_BITS, T_ADC_CONVERSION, V_REFN_CAL, V_REFP_CAL, V_REF_UNCAL};
use crate::util::rng::Pcg64;

/// One SAR ADC instance.
#[derive(Clone, Copy, Debug)]
pub struct SarAdc {
    /// Positive reference (V).
    pub v_refp: f64,
    /// Negative reference (V).
    pub v_refn: f64,
    /// Comparator input-referred offset (V), from Monte-Carlo sampling.
    pub cmp_offset: f64,
    /// Per-decision comparator noise sigma (V).
    pub cmp_noise: f64,
}

impl SarAdc {
    /// Calibrated references (Fig. 12a, full 0–63 code utilization).
    pub fn calibrated() -> SarAdc {
        SarAdc { v_refp: V_REFP_CAL, v_refn: V_REFN_CAL, cmp_offset: 0.0, cmp_noise: 0.0 }
    }

    /// Uncalibrated: full-scale VDD reference (codes 7–48 only).
    pub fn uncalibrated() -> SarAdc {
        SarAdc { v_refp: V_REF_UNCAL, v_refn: 0.0, cmp_offset: 0.0, cmp_noise: 0.0 }
    }

    /// Set the comparator offset (builder style).
    pub fn with_offset(mut self, offset: f64) -> SarAdc {
        self.cmp_offset = offset;
        self
    }

    /// Set the per-decision comparator noise sigma (builder style).
    pub fn with_noise(mut self, sigma: f64) -> SarAdc {
        self.cmp_noise = sigma;
        self
    }

    /// Run the successive-approximation binary search on input `v`.
    /// Returns the raw (uninverted) code in [0, 63].
    pub fn convert_raw(&self, v: f64, mut rng: Option<&mut Pcg64>) -> u32 {
        let mut code = 0u32;
        let fs = self.v_refp - self.v_refn;
        for bit in (0..ADC_BITS).rev() {
            let trial = code | (1 << bit);
            // CDAC comparison level for the trial code. The +0.5 LSB makes
            // the decision thresholds sit mid-step, matching round-to-
            // nearest (standard SAR with half-LSB CDAC shift).
            let v_dac = self.v_refn + fs * (trial as f64 - 0.5) / ((1u64 << ADC_BITS) as f64 - 1.0);
            let noise = match rng.as_mut() {
                Some(r) if self.cmp_noise > 0.0 => r.normal(0.0, self.cmp_noise),
                _ => 0.0,
            };
            if v + self.cmp_offset + noise >= v_dac {
                code = trial;
            }
        }
        code
    }

    /// Convert and apply the post-processing inversion (`V = VDD − MAC`,
    /// §IV-B), giving a code that increases with MAC.
    pub fn convert(&self, v: f64, rng: Option<&mut Pcg64>) -> u32 {
        let max = (1u32 << ADC_BITS) - 1;
        max - self.convert_raw(v, rng)
    }

    /// Conversion latency (s): 8 cycles at 50 MHz.
    pub fn latency(&self) -> f64 {
        T_ADC_CONVERSION
    }

    /// Code width of one LSB in volts.
    pub fn lsb(&self) -> f64 {
        (self.v_refp - self.v_refn) / ((1u64 << ADC_BITS) as f64 - 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn binary_search_equals_rounding() {
        // The SAR loop with a half-LSB-shifted CDAC must agree with ideal
        // round-to-nearest quantization — this ties the behavioral ADC to
        // TransferModel::adc_code.
        let adc = SarAdc::calibrated();
        for i in 0..=1000 {
            let v = adc.v_refn + (adc.v_refp - adc.v_refn) * i as f64 / 1000.0;
            let x = (v - adc.v_refn) / (adc.v_refp - adc.v_refn);
            let want = (x * 63.0).round().clamp(0.0, 63.0) as u32;
            let got = adc.convert_raw(v, None);
            assert_eq!(got, want, "v={v}");
        }
    }

    #[test]
    fn clamps_out_of_range() {
        let adc = SarAdc::calibrated();
        assert_eq!(adc.convert_raw(-1.0, None), 0);
        assert_eq!(adc.convert_raw(2.0, None), 63);
    }

    #[test]
    fn inversion() {
        let adc = SarAdc::calibrated();
        assert_eq!(adc.convert(adc.v_refn, None), 63);
        assert_eq!(adc.convert(adc.v_refp, None), 0);
    }

    #[test]
    fn offset_shifts_codes() {
        let adc = SarAdc::calibrated();
        let shifted = SarAdc::calibrated().with_offset(2.5 * adc.lsb());
        let v = 0.5 * (adc.v_refp + adc.v_refn);
        let d = shifted.convert_raw(v, None) as i64 - adc.convert_raw(v, None) as i64;
        assert!(d >= 2 && d <= 3, "offset moved code by {d}");
    }

    #[test]
    fn noise_dithers_near_threshold() {
        let adc = SarAdc::calibrated().with_noise(0.003);
        let mut rng = Pcg64::seeded(4);
        // Bias exactly between two codes: noise must produce both.
        let v = adc.v_refn + 10.5 * adc.lsb();
        let mut seen = std::collections::HashSet::new();
        for _ in 0..200 {
            seen.insert(adc.convert_raw(v, Some(&mut rng)));
        }
        assert!(seen.len() >= 2, "noise should dither the LSB: {seen:?}");
    }

    #[test]
    fn monotone_in_input() {
        let adc = SarAdc::uncalibrated();
        let mut prev = 0;
        for i in 0..=500 {
            let v = i as f64 * 0.8 / 500.0;
            let c = adc.convert_raw(v, None);
            assert!(c >= prev);
            prev = c;
        }
    }

    #[test]
    fn latency_matches_paper() {
        assert_eq!(SarAdc::calibrated().latency(), 160.0e-9);
    }
}
