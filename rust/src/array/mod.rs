//! Array level (§IV): the 8 KB, 128×512 6T-2R sub-array and its analog
//! periphery.
//!
//! * [`subarray`] — cell-accurate 128×512 array: weight programming, SRAM
//!   row traffic, and the massively parallel two-cycle PIM MAC.
//! * [`powerline`] — per-column VDD current accumulation with the
//!   self-consistent line/WCC loading solve.
//! * [`wcc`] — the weighted-configuration circuit: 8:4:2:1 current mirror
//!   combining the four bit-columns of each word (Fig. 6c).
//! * [`sample_hold`] — sampling capacitor with droop + kT/C noise.
//! * [`sar_adc`] — behavioral 6-bit SAR: binary search against a CDAC,
//!   comparator offset, calibrated/uncalibrated reference modes (Fig. 6d).
//! * [`fsm`] — the shared control FSM sequencing the PIM sub-phases
//!   (1.5 ns settle / 1 ns sample / 1 ns restore, then conversion).

pub mod fsm;
pub mod powerline;
pub mod sample_hold;
pub mod sar_adc;
pub mod subarray;
pub mod wcc;

pub use sar_adc::SarAdc;
pub use subarray::SubArray;
