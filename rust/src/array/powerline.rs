//! Powerline current accumulation.
//!
//! During the PIM sampling window the active side's VDD line is held near
//! the WCC reference; every active cell on the column sources a current set
//! by its RRAM state. The summed current drops part of the drive across the
//! line + WCC input stage, so the operating point is a fixed-point problem:
//!
//! ```text
//! v_line = V_REF + I_total(v_line) · R_LOAD
//! ```
//!
//! solved here by damped iteration against the *cell-accurate* current
//! model ([`crate::cell::bitcell::BitCell::pim_current`]). The closed-form
//! first-order solution of the same equation is what
//! [`crate::pim::transfer::TransferModel`] uses; `subarray` tests verify the
//! two agree to within an ADC LSB.

use crate::cell::bitcell::{BitCell, Side};
use crate::consts::VDD;
use crate::pim::transfer::V_REF;

/// Result of one column-line accumulation.
#[derive(Clone, Copy, Debug)]
pub struct LineSolve {
    /// Total sampled current (A).
    pub current: f64,
    /// Settled line voltage at the cells (V).
    pub v_line: f64,
    /// Iterations used.
    pub iters: u32,
}

/// Solve the self-consistent line current for one bit-column of cells, on
/// `side`, with per-row input activations `ia`. `r_load` is the effective
/// line + mirror input resistance (Ω); weighting by the WCC happens after
/// this (per-bit-line solve — the mirror input is the summing node, so the
/// loading applies to the *weighted* current; the caller passes the
/// bit-significance-scaled r_load accordingly, see `wcc.rs`).
pub fn solve_line(
    cells: &[BitCell],
    ia: &[bool],
    side: Side,
    r_load: f64,
) -> LineSolve {
    assert_eq!(cells.len(), ia.len());
    let mut v_line = V_REF;
    let mut current = total_current(cells, ia, side, v_line);
    let mut iters = 0;
    for _ in 0..40 {
        iters += 1;
        let v_next = V_REF + current * r_load;
        // Damping keeps the iteration stable even at FF full-scale.
        let v_new = 0.5 * v_line + 0.5 * v_next.min(VDD);
        let i_new = total_current(cells, ia, side, v_new);
        if (v_new - v_line).abs() < 1e-7 && (i_new - current).abs() < 1e-10 {
            v_line = v_new;
            current = i_new;
            break;
        }
        v_line = v_new;
        current = i_new;
    }
    LineSolve { current, v_line, iters }
}

fn total_current(cells: &[BitCell], ia: &[bool], side: Side, v_line: f64) -> f64 {
    cells
        .iter()
        .zip(ia)
        .map(|(c, &a)| c.pim_current(side, a, v_line))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::Corner;

    fn column(n_lrs: usize, n_total: usize, q: bool) -> (Vec<BitCell>, Vec<bool>) {
        let cells: Vec<BitCell> = (0..n_total)
            .map(|i| {
                let mut c = BitCell::with_weight_bit(Corner::TT, i < n_lrs);
                c.q = q;
                c
            })
            .collect();
        let ia = vec![true; n_total];
        (cells, ia)
    }

    #[test]
    fn current_scales_with_active_rows() {
        // 16 vs 64 LRS rows (remaining rows HRS, still active): the raw
        // line current scales sub-4× because of the HRS background (the
        // sub-array's reference-column calibration removes it; here we see
        // the physical uncorrected current).
        let (c1, ia1) = column(16, 128, true);
        let (c2, ia2) = column(64, 128, true);
        let s1 = solve_line(&c1, &ia1, Side::Left, 0.8);
        let s2 = solve_line(&c2, &ia2, Side::Left, 0.8);
        let ratio = s2.current / s1.current;
        assert!(ratio > 3.0 && ratio < 4.05, "ratio = {ratio}");
        // Net of the HRS background the scaling is ~4×.
        let hrs_unit = {
            let (c0, ia0) = column(0, 128, true);
            solve_line(&c0, &ia0, Side::Left, 0.8).current / 128.0
        };
        let net1 = s1.current - 112.0 * hrs_unit;
        let net2 = s2.current - 64.0 * hrs_unit;
        let net_ratio = net2 / net1;
        assert!(net_ratio > 3.8 && net_ratio < 4.1, "net ratio = {net_ratio}");
    }

    #[test]
    fn loading_compresses_large_sums() {
        let (cells, ia) = column(128, 128, true);
        let ideal = solve_line(&cells, &ia, Side::Left, 0.0);
        let loaded = solve_line(&cells, &ia, Side::Left, 50.0);
        assert!(loaded.current < ideal.current);
        assert!(loaded.v_line > ideal.v_line);
    }

    #[test]
    fn inactive_side_near_zero() {
        let (cells, ia) = column(128, 128, true); // q=1 ⇒ right side inactive
        let s = solve_line(&cells, &ia, Side::Right, 0.8);
        assert!(s.current < 1e-6, "i = {}", s.current);
    }

    #[test]
    fn converges_quickly() {
        let (cells, ia) = column(128, 128, true);
        let s = solve_line(&cells, &ia, Side::Left, 100.0);
        assert!(s.iters <= 40);
        assert!(s.current.is_finite() && s.v_line.is_finite());
    }
}
