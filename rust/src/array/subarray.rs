//! Cell-accurate 128×512 6T-2R sub-array (§IV-A).
//!
//! Geometry: 128 rows × 128 words × 4 bits. VSS/wordlines run along rows;
//! VDD lines + bitlines along columns. Weights live in the RRAMs (both
//! devices of a cell hold the same bit); the SRAM latches hold ordinary
//! cache data that PIM operations must not disturb.
//!
//! The PIM MAC follows the real hardware pipeline — per side:
//! per-bit-column powerline accumulation → WCC 8:4:2:1 weighting with
//! summing-node compression → S&H → per-word 6-bit SAR conversion — and
//! the two sides' estimates are combined digitally, so the result is
//! independent of the stored cache data (verified by tests + the
//! `cache_retention` example).
//!
//! Hot-path note: each cell's PIM path conductance is cached on weight
//! load (the full nonlinear divider solve is collapsed to its operating
//! point at V_REF); `powerline::solve_line` remains the exact reference
//! and the `agrees_with_exact_line_solve` test bounds the error.

use crate::cell::bitcell::{BitCell, Side};
use crate::cell::timing::{EnergyLedger, OpKind};
use crate::consts::{ARRAY_ROWS, ARRAY_WORDS, VDD, WORD_BITS};
use crate::device::{Corner, VariationModel};
use crate::pim::transfer::{TransferModel, V_REF};
use crate::util::rng::Pcg64;

use super::fsm::PimFsm;
use super::sample_hold::SampleHold;
use super::sar_adc::SarAdc;

/// One 8 KB sub-array.
pub struct SubArray {
    /// Process corner of every cell.
    pub corner: Corner,
    /// All 128×512 cells, row-major `[row][word][bit]`.
    pub cells: Vec<BitCell>,
    /// Cached per-cell *calibrated* PIM path conductance (S) at the V_REF
    /// operating point: `[row * 512 + word * 4 + bit]`, per side.
    ///
    /// Calibration (mirrors what §V-C's reference trimming does on the real
    /// macro): the nominal HRS background conductance is subtracted
    /// (reference-column offset cancellation) and the result is gain-trimmed
    /// so a nominal LRS cell contributes exactly the transfer model's
    /// `i_unit`. Residuals are the *physical* error sources: RRAM/FET
    /// mismatch and the FET divider's bias dependence.
    g_left: Vec<f32>,
    g_right: Vec<f32>,
    /// Shared sample-and-hold stage.
    pub sh: SampleHold,
    /// Per-word-column SAR ADC (one modeled instance).
    pub adc: SarAdc,
    /// PIM sub-phase control FSM.
    pub fsm: PimFsm,
    /// WCC summing-node load (Ω), per the corner (TransferModel contract).
    pub r_load: f64,
    /// Latency/energy accounting for every metered operation.
    pub ledger: EnergyLedger,
}

impl SubArray {
    /// Nominal (variation-free) sub-array at a corner.
    pub fn new(corner: Corner) -> SubArray {
        Self::build(corner, None, 0)
    }

    /// With Monte-Carlo per-cell variation (deterministic by seed).
    pub fn with_variation(corner: Corner, var: &VariationModel, seed: u64) -> SubArray {
        Self::build(corner, Some(*var), seed)
    }

    fn build(corner: Corner, var: Option<VariationModel>, seed: u64) -> SubArray {
        let mut rng = Pcg64::seeded(seed);
        let n = ARRAY_ROWS * ARRAY_WORDS * WORD_BITS;
        let cells = (0..n)
            .map(|_| match &var {
                Some(v) => BitCell::with_variation(corner, v.sample_cell(&mut rng)),
                None => BitCell::new(corner),
            })
            .collect();
        let transfer = TransferModel::new(corner);
        let vm = var.unwrap_or_else(VariationModel::none);
        let mut sa = SubArray {
            corner,
            cells,
            g_left: vec![0.0; n],
            g_right: vec![0.0; n],
            sh: SampleHold::new(&transfer, &vm),
            adc: SarAdc::calibrated().with_offset(if vm.sigma_cmp_offset > 0.0 {
                vm.sample_cmp_offset(&mut rng)
            } else {
                0.0
            }),
            fsm: PimFsm::new(),
            r_load: transfer.r_load,
            ledger: EnergyLedger::new(),
        };
        sa.refresh_conductances();
        sa
    }

    #[inline]
    fn idx(row: usize, word: usize, bit: usize) -> usize {
        row * (ARRAY_WORDS * WORD_BITS) + word * WORD_BITS + bit
    }

    /// Raw (uncalibrated) path conductance of one cell on one side.
    fn g_raw(cell: &BitCell, side: Side) -> f64 {
        let drive = VDD - V_REF;
        let mut cc = cell.clone();
        cc.q = side == Side::Left; // force the side active for probing
        cc.pim_current(side, true, V_REF) / drive
    }

    /// Nominal (variation-free) probe conductances for calibration.
    fn calibration_trim(&self) -> (f64, f64) {
        let lrs = BitCell::with_weight_bit(self.corner, true);
        let hrs = BitCell::with_weight_bit(self.corner, false);
        let g_lrs = Self::g_raw(&lrs, Side::Left);
        let g_hrs = Self::g_raw(&hrs, Side::Left);
        let drive = VDD - V_REF;
        let g_target = TransferModel::new(self.corner).i_unit / drive;
        let trim = g_target / (g_lrs - g_hrs);
        (g_hrs, trim)
    }

    /// Recompute the cached calibrated path conductances from cell state.
    pub fn refresh_conductances(&mut self) {
        let (g_hrs_nom, trim) = self.calibration_trim();
        for (i, c) in self.cells.iter().enumerate() {
            self.g_left[i] = ((Self::g_raw(c, Side::Left) - g_hrs_nom) * trim) as f32;
            self.g_right[i] = ((Self::g_raw(c, Side::Right) - g_hrs_nom) * trim) as f32;
        }
    }

    // ---------------------------------------------------------- weights

    /// Fast-load 4-bit weights (one per word): `weights[word]` replicated
    /// across... no — `weights` is row-major `[row][word]`, each 0..=15.
    /// Both RRAMs of each cell receive the same bit (§III-A symmetry).
    pub fn load_weights(&mut self, weights: &[u8]) {
        assert_eq!(weights.len(), ARRAY_ROWS * ARRAY_WORDS);
        for row in 0..ARRAY_ROWS {
            for word in 0..ARRAY_WORDS {
                let w = weights[row * ARRAY_WORDS + word];
                assert!(w <= 15);
                for bit in 0..WORD_BITS {
                    let cell = &mut self.cells[Self::idx(row, word, bit)];
                    cell.set_weight_bit((w >> bit) & 1 == 1);
                }
            }
        }
        self.refresh_conductances();
    }

    /// Electrically program one cell's weight bit through the §III-A pulse
    /// sequences (destructive to that cell's SRAM data; costs metered).
    pub fn program_cell(&mut self, row: usize, word: usize, bit: usize, value: bool) -> bool {
        let cell = &mut self.cells[Self::idx(row, word, bit)];
        let ok = if value {
            let a = cell.program_lrs(Side::Left, &mut self.ledger);
            let b = cell.program_lrs(Side::Right, &mut self.ledger);
            a.verified && b.verified
        } else {
            cell.program_hrs(&mut self.ledger).verified
        };
        let (g_hrs_nom, trim) = self.calibration_trim();
        let i = Self::idx(row, word, bit);
        let c = &self.cells[i];
        self.g_left[i] = ((Self::g_raw(c, Side::Left) - g_hrs_nom) * trim) as f32;
        self.g_right[i] = ((Self::g_raw(c, Side::Right) - g_hrs_nom) * trim) as f32;
        ok
    }

    // ---------------------------------------------------------- SRAM mode

    /// Write one 512-bit row of cache data (bits packed little-endian in 64
    /// bytes).
    pub fn sram_write_row(&mut self, row: usize, data: &[u8; 64]) {
        self.ledger.record(OpKind::SramWrite);
        for col in 0..(ARRAY_WORDS * WORD_BITS) {
            let bit = (data[col / 8] >> (col % 8)) & 1 == 1;
            self.cells[row * 512 + col].q = bit;
        }
    }

    /// Read one 512-bit row.
    pub fn sram_read_row(&mut self, row: usize) -> [u8; 64] {
        self.ledger.record(OpKind::SramRead6t2r);
        let mut out = [0u8; 64];
        for col in 0..(ARRAY_WORDS * WORD_BITS) {
            if self.cells[row * 512 + col].q {
                out[col / 8] |= 1 << (col % 8);
            }
        }
        out
    }

    /// Snapshot of all latch states (for retention verification).
    pub fn sram_snapshot(&self) -> Vec<bool> {
        self.cells.iter().map(|c| c.q).collect()
    }

    // ---------------------------------------------------------- PIM mode

    /// Weighted (WCC-combined, compressed) current for one word on one
    /// side, for a 1-bit activation vector.
    pub fn word_current(&self, ia: &[bool], word: usize, side: Side) -> f64 {
        debug_assert_eq!(ia.len(), ARRAY_ROWS);
        let g = match side {
            Side::Left => &self.g_left,
            Side::Right => &self.g_right,
        };
        let drive = VDD - V_REF;
        let mut weighted = 0.0f64;
        for bit in 0..WORD_BITS {
            let mut i_line = 0.0f64;
            for (row, &a) in ia.iter().enumerate() {
                if !a {
                    continue;
                }
                let cell = &self.cells[Self::idx(row, word, bit)];
                let active = match side {
                    Side::Left => cell.q,
                    Side::Right => !cell.q,
                };
                if active {
                    i_line += g[Self::idx(row, word, bit)] as f64 * drive;
                }
            }
            weighted += (1u32 << bit) as f64 * i_line;
        }
        // Summing-node compression (TransferModel contract).
        weighted / (1.0 + weighted * self.r_load / drive)
    }

    /// One full bit-plane PIM step over all words on one side: analog
    /// cycle + per-word conversion. Returns per-word inverted ADC codes.
    pub fn pim_plane(&mut self, ia: &[bool], side: Side, rng: Option<&mut Pcg64>) -> Vec<u32> {
        let mut fsm = std::mem::take(&mut self.fsm);
        fsm.run_side_cycle(ARRAY_WORDS, &mut self.ledger);
        self.fsm = fsm;
        let mut rng = rng;
        (0..ARRAY_WORDS)
            .map(|w| {
                let i = self.word_current(ia, w, side);
                let v = self.sh.sample(i, 0.0, rng.as_deref_mut());
                self.adc.convert(v, rng.as_deref_mut())
            })
            .collect()
    }

    /// Complete 4-bit × 4-bit MAC (§IV-B): bit-serial planes × both sides,
    /// digital shift-add; returns per-word dequantized MAC estimates.
    /// The stored cache data is untouched (asserted in debug builds).
    pub fn pim_mac_4b(&mut self, ia4: &[u8], mut rng: Option<&mut Pcg64>) -> Vec<f32> {
        assert_eq!(ia4.len(), ARRAY_ROWS);
        debug_assert!(ia4.iter().all(|&x| x <= 15));
        #[cfg(debug_assertions)]
        let snap = self.sram_snapshot();
        let transfer = TransferModel::new(self.corner);
        // Digital zero-offset correction: a zero partial sum converts to a
        // nonzero code (the S&H zero level sits one step inside the ADC's
        // positive reference — visible as "code 1 at weight 0" in Fig. 12a).
        // The post-processing subtractor removes it per conversion.
        let zero_est = {
            let code0 = self.adc.convert(self.sh.sample_ideal(0.0), None);
            transfer.mac_estimate(code0)
        };
        let mut out = vec![0.0f32; ARRAY_WORDS];
        for plane in 0..4u32 {
            let ia: Vec<bool> = ia4.iter().map(|&x| (x >> plane) & 1 == 1).collect();
            let left = self.pim_plane(&ia, Side::Left, rng.as_deref_mut());
            let right = self.pim_plane(&ia, Side::Right, rng.as_deref_mut());
            for (o, (l, r)) in out.iter_mut().zip(left.iter().zip(right.iter())) {
                // Digital combine: the two sides' partial sums (each row
                // contributes on exactly one side, §III-C), each
                // offset-corrected.
                let est = (transfer.mac_estimate(*l) - zero_est).max(0.0)
                    + (transfer.mac_estimate(*r) - zero_est).max(0.0);
                *o += (1u32 << plane) as f32 * est as f32;
                self.ledger.record(OpKind::DigitalPostOp);
            }
        }
        #[cfg(debug_assertions)]
        debug_assert_eq!(snap, self.sram_snapshot(), "PIM must retain cache data");
        out
    }

    /// The exact integer MAC for verification: Σ_rows ia4[r] · weight[r][w].
    pub fn exact_mac(&self, ia4: &[u8], word: usize) -> u32 {
        (0..ARRAY_ROWS)
            .map(|row| {
                let mut w = 0u32;
                for bit in 0..WORD_BITS {
                    if self.cells[Self::idx(row, word, bit)].weight_bit() {
                        w |= 1 << bit;
                    }
                }
                ia4[row] as u32 * w
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ramp_weights() -> Vec<u8> {
        (0..ARRAY_ROWS * ARRAY_WORDS)
            .map(|i| ((i / ARRAY_WORDS + i % ARRAY_WORDS) % 16) as u8)
            .collect()
    }

    fn small_array() -> SubArray {
        let mut sa = SubArray::new(Corner::TT);
        sa.load_weights(&ramp_weights());
        sa
    }

    #[test]
    fn sram_rw_roundtrip_with_weights_loaded() {
        let mut sa = small_array();
        let mut data = [0u8; 64];
        for (i, b) in data.iter_mut().enumerate() {
            *b = (i as u8).wrapping_mul(37) ^ 0x5a;
        }
        sa.sram_write_row(3, &data);
        assert_eq!(sa.sram_read_row(3), data);
    }

    #[test]
    fn pim_mac_tracks_exact_and_retains_data() {
        let mut sa = small_array();
        // Scatter cache data across the array.
        let mut rng = Pcg64::seeded(11);
        for row in 0..ARRAY_ROWS {
            let mut d = [0u8; 64];
            for b in d.iter_mut() {
                *b = rng.next_u64() as u8;
            }
            sa.sram_write_row(row, &d);
        }
        let snap = sa.sram_snapshot();
        let ia4: Vec<u8> = (0..ARRAY_ROWS).map(|r| (r % 16) as u8).collect();
        let got = sa.pim_mac_4b(&ia4, None);
        assert_eq!(sa.sram_snapshot(), snap, "cache data must be retained");
        // Accuracy: two-conversion pipeline ⇒ error per plane ≤ ~2 LSB;
        // recombined bound ≈ 2·LSB·15. Check a representative subset.
        let lsb = 1920.0 / 63.0;
        for w in (0..ARRAY_WORDS).step_by(17) {
            let exact: f64 = (0..4)
                .map(|p| {
                    let mac: u32 = (0..ARRAY_ROWS)
                        .filter(|&r| (ia4[r] >> p) & 1 == 1)
                        .map(|r| sa.exact_mac(&{
                            let mut one = vec![0u8; ARRAY_ROWS];
                            one[r] = 1;
                            one
                        }, w))
                        .sum();
                    (1u32 << p) as f64 * mac as f64
                })
                .sum();
            let err = (got[w] as f64 - exact).abs();
            assert!(err < 2.5 * lsb * 15.0, "word {w}: est {} vs exact {exact}", got[w]);
        }
    }

    #[test]
    fn result_independent_of_cache_data() {
        // The headline property: the MAC estimate does not depend on the
        // SRAM contents (rows merely contribute on different sides).
        let ia4: Vec<u8> = (0..ARRAY_ROWS).map(|r| ((r * 7) % 16) as u8).collect();
        let mut a = small_array();
        let mut b = small_array();
        // a: all zeros; b: random cache data.
        let mut rng = Pcg64::seeded(5);
        for row in 0..ARRAY_ROWS {
            let mut d = [0u8; 64];
            for byte in d.iter_mut() {
                *byte = rng.next_u64() as u8;
            }
            b.sram_write_row(row, &d);
        }
        let ra = a.pim_mac_4b(&ia4, None);
        let rb = b.pim_mac_4b(&ia4, None);
        let lsb = 1920.0 / 63.0;
        for w in 0..ARRAY_WORDS {
            let d = (ra[w] - rb[w]).abs() as f64;
            // Differences only from which side quantizes which partial sum:
            // bounded by ~1 LSB per plane recombined.
            assert!(d <= 2.0 * lsb * 15.0, "word {w}: {} vs {}", ra[w], rb[w]);
        }
        // Mean deviation across words stays well under one recombined LSB
        // (per-word bound above is the worst case; the ramp weights make
        // all words near-equal, so correlation is not a meaningful metric
        // here — the absolute agreement is).
        let mean_dev: f64 = ra
            .iter()
            .zip(rb.iter())
            .map(|(a, b)| (a - b).abs() as f64)
            .sum::<f64>()
            / ra.len() as f64;
        assert!(mean_dev < 1.0 * lsb * 15.0, "mean dev = {mean_dev}");
    }

    #[test]
    fn fullscale_current_matches_transfer_model() {
        // All weights 15, all IA bits on, all rows on one side: the word
        // current must land on TransferModel::line_current(1920) — the
        // calibration contract between the cell-accurate array and the
        // functional model.
        let mut sa = SubArray::new(Corner::TT);
        sa.load_weights(&vec![15u8; ARRAY_ROWS * ARRAY_WORDS]);
        for c in sa.cells.iter_mut() {
            c.q = true;
        }
        let ia = vec![true; ARRAY_ROWS];
        let got = sa.word_current(&ia, 0, Side::Left);
        let want = TransferModel::new(Corner::TT).line_current(1920.0);
        let rel = (got - want).abs() / want;
        assert!(rel < 0.01, "got {got} want {want}");
    }

    #[test]
    fn calibration_zeroes_hrs_background() {
        // All-HRS word: the reference-column offset subtraction must leave
        // only a negligible residual (nominal cells ⇒ ~exactly zero).
        let mut sa = SubArray::new(Corner::TT);
        sa.load_weights(&vec![0u8; ARRAY_ROWS * ARRAY_WORDS]);
        for c in sa.cells.iter_mut() {
            c.q = true;
        }
        let ia = vec![true; ARRAY_ROWS];
        let got = sa.word_current(&ia, 3, Side::Left);
        let fullscale = TransferModel::new(Corner::TT).line_current(1920.0);
        assert!(got.abs() < 0.01 * fullscale, "residual background {got}");
    }

    #[test]
    fn electrical_programming_updates_weights() {
        let mut sa = SubArray::new(Corner::TT);
        assert!(sa.program_cell(0, 0, 0, true));
        assert!(sa.cells[SubArray::idx(0, 0, 0)].weight_bit());
        assert!(sa.program_cell(0, 0, 0, false));
        assert!(!sa.cells[SubArray::idx(0, 0, 0)].weight_bit());
        assert!(sa.ledger.count(OpKind::ProgramPulse) >= 3);
    }

    #[test]
    fn ledger_counts_full_mac() {
        let mut sa = small_array();
        sa.ledger.reset();
        let ia4 = vec![5u8; ARRAY_ROWS];
        sa.pim_mac_4b(&ia4, None);
        // 2 sides × 4 planes = 8 array cycles; 8 × 128 conversions.
        assert_eq!(sa.ledger.count(OpKind::PimArrayCycle), 8);
        assert_eq!(sa.ledger.count(OpKind::AdcConversion), 8 * 128);
    }
}
