//! Weighted Configuration Circuit (WCC) — Fig. 6(c).
//!
//! Each 4-bit word exposes four VDD lines per side; the WCC's NMOS current
//! mirrors scale them 8:4:2:1 (MSB→LSB) and combine them in the current
//! domain at a single summing node. The mirror input stage presents the
//! loading resistance that produces the corner-dependent compression
//! (see [`crate::pim::transfer`] for the closed form).

use crate::cell::bitcell::{BitCell, Side};
use crate::consts::WORD_BITS;
use crate::device::Corner;

use super::powerline;

/// WCC instance for one word column (one side).
#[derive(Clone, Copy, Debug)]
pub struct Wcc {
    /// Process corner (sets the summing-node loading).
    pub corner: Corner,
    /// Summing-node input resistance (Ω) — the compression knob, matched to
    /// `TransferModel::r_load` per corner.
    pub r_load: f64,
    /// Multiplicative mirror gain error per bit (nominal 1.0).
    pub mirror_gain: [f64; WORD_BITS],
}

impl Wcc {
    /// WCC with the corner's nominal loading and unit mirror gains.
    pub fn new(corner: Corner) -> Wcc {
        let r_load = match corner {
            Corner::SS => 0.6,
            Corner::TT => 0.8,
            Corner::FF => 3.2,
        };
        Wcc { corner, r_load, mirror_gain: [1.0; WORD_BITS] }
    }

    /// Weighted current for one word: bit-columns `cols[b]` hold the cells
    /// of weight-bit `b` (LSB..MSB); all share the row activations `ia`.
    ///
    /// The mirror scales each bit line by 2^b *before* summation, so the
    /// loading applies to the weighted total — we therefore solve each bit
    /// line with its significance-scaled share of the load (equivalent to
    /// loading the combined current to first order).
    pub fn weighted_current(
        &self,
        cols: &[Vec<BitCell>],
        ia: &[bool],
        side: Side,
    ) -> f64 {
        assert_eq!(cols.len(), WORD_BITS);
        // First pass: unloaded per-bit currents.
        let raw: Vec<f64> = cols
            .iter()
            .map(|col| powerline::solve_line(col, ia, side, 0.0).current)
            .collect();
        let weighted_raw: f64 = raw
            .iter()
            .enumerate()
            .map(|(b, i)| self.mirror_gain[b] * (1u32 << b) as f64 * i)
            .sum();
        // Apply the summing-node compression to the combined current (the
        // same first-order form as TransferModel::line_current).
        let v_swing = crate::consts::VDD - crate::pim::transfer::V_REF;
        weighted_raw / (1.0 + weighted_raw * self.r_load / v_swing)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build one word column set: weight w (4-bit) replicated down 128 rows,
    /// all cells storing Q = 1 (left side active).
    fn word_cols(w: u8, rows: usize) -> Vec<Vec<BitCell>> {
        (0..WORD_BITS)
            .map(|b| {
                (0..rows)
                    .map(|_| {
                        let mut c =
                            BitCell::with_weight_bit(Corner::TT, (w >> b) & 1 == 1);
                        c.q = true;
                        c
                    })
                    .collect()
            })
            .collect()
    }

    #[test]
    fn binary_weighting_is_monotone_in_w() {
        let ia = vec![true; 128];
        let wcc = Wcc::new(Corner::TT);
        let mut prev = -1.0;
        for w in 0..16u8 {
            let cols = word_cols(w, 128);
            let i = wcc.weighted_current(&cols, &ia, Side::Left);
            assert!(i > prev, "w={w}: {i} !> {prev}");
            prev = i;
        }
    }

    #[test]
    fn msb_dominates() {
        let ia = vec![true; 128];
        let wcc = Wcc::new(Corner::TT);
        let i8 = wcc.weighted_current(&word_cols(8, 128), &ia, Side::Left);
        let i7 = wcc.weighted_current(&word_cols(7, 128), &ia, Side::Left);
        // 8 > 7 must hold through the analog chain (binary weighting).
        assert!(i8 > i7, "{i8} vs {i7}");
        // And w=8 vs w=1 shows the binary ratio diluted by the HRS
        // background of the off bit-columns (removed downstream by the
        // sub-array's reference calibration).
        let i1 = wcc.weighted_current(&word_cols(1, 128), &ia, Side::Left);
        let ratio = i8 / i1;
        assert!(ratio > 4.5 && ratio < 8.5, "ratio = {ratio}");
    }

    #[test]
    fn gain_error_shifts_current() {
        let ia = vec![true; 128];
        let mut wcc = Wcc::new(Corner::TT);
        let nominal = wcc.weighted_current(&word_cols(15, 128), &ia, Side::Left);
        wcc.mirror_gain[3] = 1.05;
        let skewed = wcc.weighted_current(&word_cols(15, 128), &ia, Side::Left);
        assert!(skewed > nominal);
    }

    #[test]
    fn ff_compresses_more_than_tt() {
        let ia = vec![true; 128];
        let mk = |corner: Corner| {
            let cols: Vec<Vec<BitCell>> = (0..WORD_BITS)
                .map(|_| {
                    (0..128)
                        .map(|_| {
                            let mut c = BitCell::with_weight_bit(corner, true);
                            c.q = true;
                            c
                        })
                        .collect()
                })
                .collect();
            let wcc = Wcc::new(corner);
            let raw: f64 = (0..WORD_BITS)
                .map(|b| {
                    (1u32 << b) as f64
                        * powerline::solve_line(&cols[b], &ia, Side::Left, 0.0).current
                })
                .sum();
            let eff = wcc.weighted_current(&cols, &ia, Side::Left);
            eff / raw // compression factor (1.0 = none)
        };
        assert!(mk(Corner::FF) < mk(Corner::TT));
    }
}
