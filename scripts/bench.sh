#!/usr/bin/env bash
# Machine-readable perf-trajectory record for this PR: runs the hot-path
# micro-benchmarks plus the fleet-sim summary and writes BENCH_PR3.json at
# the repository root (so BENCH_*.json accumulates across PRs).
#
# Usage: scripts/bench.sh [output.json]
set -euo pipefail

cd "$(dirname "$0")/.."

OUT="${1:-BENCH_PR3.json}"

cargo run --release --bin repro -- bench --json "$OUT"
echo "bench: wrote $OUT"
