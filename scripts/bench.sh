#!/usr/bin/env bash
# Machine-readable perf-trajectory record for this PR: runs the hot-path
# micro-benchmarks (serial vs N-thread tiled execution, plus the
# simd_vs_scalar MAC-kernel race) and the fleet-sim summary, then writes
# BENCH_PR6.json at the repository root (so BENCH_*.json accumulates
# across PRs — see PERFORMANCE.md).
#
# The record has two sections: `comparison` (deterministic — workload
# descriptors, bit-exactness parity verdicts including the
# simd_vs_scalar kernel-parity gate, the simulated-clock fleet report)
# diffs cleanly across PRs; `measured` carries the wall-clock numbers
# for this machine.
#
# Usage: scripts/bench.sh [output.json] [threads]
set -euo pipefail

cd "$(dirname "$0")/.."

OUT="${1:-BENCH_PR6.json}"
THREADS="${2:-4}"

cargo run --release --bin repro -- bench --json "$OUT" --threads "$THREADS"
echo "bench: wrote $OUT (threads=$THREADS)"
