#!/usr/bin/env bash
# Machine-readable perf-trajectory record for this PR: runs the hot-path
# micro-benchmarks (serial vs N-thread tiled execution, plus the
# simd_vs_scalar MAC-kernel race), the serve section (front-door knee
# determinism, M/D/c queueing cross-check, merged-execution parity), the
# shard section (pipelined shard-executor parity, over-capacity
# placement, hop-transfer attribution), the hotpath section (persistent
# worker-pool dispatch vs spawn-per-call, zero-skip/zero-alloc/
# spawn-once gates), and the fleet-sim summary, then writes
# BENCH_PR10.json at the repository root (so BENCH_*.json accumulates
# across PRs — see PERFORMANCE.md).
#
# The record has two sections: `comparison` (deterministic — workload
# descriptors, bit-exactness parity verdicts including the
# simd_vs_scalar kernel-parity and comparison.serve gates, the
# simulated-clock fleet/serve reports) diffs cleanly across PRs;
# `measured` carries the wall-clock numbers for this machine.
#
# Provenance: after the run, the JSON is stamped with the commit and
# toolchain that produced it ({"kind": "measured", ...}) so a snapshot
# measured here is machine-distinguishable from a hand-authored one
# ({"kind": "hand-authored"} or the legacy string form) — see
# scripts/bench_compare.sh.
#
# Usage: scripts/bench.sh [output.json] [threads]
set -euo pipefail

cd "$(dirname "$0")/.."

OUT="${1:-BENCH_PR10.json}"
THREADS="${2:-4}"

cargo run --release --bin repro -- bench --json "$OUT" --threads "$THREADS"

if command -v python3 >/dev/null 2>&1; then
  GIT_HEAD="$(git rev-parse HEAD 2>/dev/null || echo unknown)"
  RUSTC_V="$(rustc --version 2>/dev/null || echo unknown)"
  python3 - "$OUT" "$GIT_HEAD" "$RUSTC_V" <<'EOF'
import json, sys
path, git_head, rustc_v = sys.argv[1], sys.argv[2], sys.argv[3]
with open(path) as f:
    doc = json.load(f)
doc["provenance"] = {"kind": "measured", "git": git_head, "rustc": rustc_v}
with open(path, "w") as f:
    json.dump(doc, f, sort_keys=True, separators=(",", ":"))
EOF
  echo "bench: stamped provenance (git $GIT_HEAD)"
else
  echo "bench: python3 unavailable, provenance not stamped"
fi

echo "bench: wrote $OUT (threads=$THREADS)"
