#!/usr/bin/env bash
# Machine-readable perf-trajectory record for this PR: runs the hot-path
# micro-benchmarks (serial vs N-thread tiled execution) plus the fleet-sim
# summary and writes BENCH_PR5.json at the repository root (so
# BENCH_*.json accumulates across PRs — see PERFORMANCE.md).
#
# The record has two sections: `comparison` (deterministic — workload
# descriptors, bit-exactness parity verdicts, the simulated-clock fleet
# report) diffs cleanly across PRs; `measured` carries the wall-clock
# numbers for this machine.
#
# Usage: scripts/bench.sh [output.json] [threads]
set -euo pipefail

cd "$(dirname "$0")/.."

OUT="${1:-BENCH_PR5.json}"
THREADS="${2:-4}"

cargo run --release --bin repro -- bench --json "$OUT" --threads "$THREADS"
echo "bench: wrote $OUT (threads=$THREADS)"
