#!/usr/bin/env bash
# Tier-1 verification gate for the NVM-in-Cache reproduction:
#   1. release build (lib + repro bin + examples + benches)
#   2. full test suite (+ the simd_parity and serve_sim suites re-run in
#      release, where lane-packing and numeric-crosscheck bugs surface)
#   3. doctests, explicitly (the runnable `# Examples` on the key public
#      APIs — PimEngine, TransferModel, place_from, FleetRouter, Server, …)
#   4. rustdoc build with warnings denied (crate carries
#      #![warn(missing_docs)]; broken intra-doc links fail the gate)
#   5. cargo fmt --check (when the rustfmt component is installed)
#   6. cargo clippy -- -D warnings (when the clippy component is installed)
#   7. bench_compare.sh over the two newest BENCH_PR*.json trajectory
#      records (when ≥2 exist and python3 is available) — fails the gate
#      on a parity regression in the deterministic comparison section
#
# Run from anywhere inside the repository; fully offline.
set -euo pipefail

cd "$(dirname "$0")/.."

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

# The word-wide MAC kernel's u64 lane packing only gets exercised with
# optimizations on (autovectorized popcounts, folded shifts); run the
# differential suite in release too, where those bugs actually surface.
if [ -f rust/tests/simd_parity.rs ]; then
  echo "== cargo test --release -q --test simd_parity =="
  cargo test --release -q --test simd_parity
fi

# Serving tests in release too: the front-door sweep + merged stepped
# execution across thread counts are much faster with optimizations on,
# and the M/D/c numeric cross-check must hold in both profiles.
if [ -f rust/tests/serve_sim.rs ]; then
  echo "== cargo test --release -q --test serve_sim =="
  cargo test --release -q --test serve_sim
fi

# Shard-parity differential suite in release too: the pipelined shard
# executor races the solo forward bit-for-bit (logits + RNG stream)
# across shard/thread counts, and the heavy noisy-mode matrix is only
# tolerable with optimizations on.
if [ -f rust/tests/shard_parity.rs ]; then
  echo "== cargo test --release -q --test shard_parity =="
  cargo test --release -q --test shard_parity
fi

# Transformer differential suite in release too: the compiled attention
# block races the straight-line spec (and the dense fp32 witness)
# bit-for-bit across kernels/threads/modes, and the ragged-shape sweep
# over word/block edges is only tolerable with optimizations on.
if [ -f rust/tests/transformer_parity.rs ]; then
  echo "== cargo test --release -q --test transformer_parity =="
  cargo test --release -q --test transformer_parity
fi

# Hotpath differential suite in release too: the persistent worker
# pool's memory-ordering (park/claim/done-chain) and the zero-word skip
# only get truly exercised with optimizations on, and the pooled vs
# spawn-per-call parity must hold in both profiles.
if [ -f rust/tests/hotpath_parity.rs ]; then
  echo "== cargo test --release -q --test hotpath_parity =="
  cargo test --release -q --test hotpath_parity
fi

echo "== cargo test --doc =="
cargo test --doc -q

echo "== RUSTDOCFLAGS=-D warnings cargo doc --no-deps =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

if cargo fmt --version >/dev/null 2>&1; then
  echo "== cargo fmt --check =="
  cargo fmt --check
else
  echo "== cargo fmt --check: rustfmt not installed, skipping =="
fi

if cargo clippy --version >/dev/null 2>&1; then
  echo "== cargo clippy -- -D warnings =="
  cargo clippy --all-targets -- -D warnings
else
  echo "== cargo clippy: clippy not installed, skipping =="
fi

# Guarded cross-PR parity gate: diff the two newest trajectory records.
if command -v python3 >/dev/null 2>&1; then
  mapfile -t BENCHES < <(ls BENCH_PR*.json 2>/dev/null | sort -V)
  if [ "${#BENCHES[@]}" -ge 2 ]; then
    OLD="${BENCHES[${#BENCHES[@]}-2]}"
    NEW="${BENCHES[${#BENCHES[@]}-1]}"
    echo "== scripts/bench_compare.sh $OLD $NEW =="
    scripts/bench_compare.sh "$OLD" "$NEW"
  else
    echo "== bench_compare: fewer than two BENCH_PR*.json records, skipping =="
  fi
else
  echo "== bench_compare: python3 not available, skipping =="
fi

echo "verify: OK"
