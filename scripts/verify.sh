#!/usr/bin/env bash
# Tier-1 verification gate for the NVM-in-Cache reproduction:
#   1. release build (lib + repro bin + examples + benches)
#   2. full test suite
#   3. rustdoc build (crate carries #![warn(missing_docs)])
#   4. cargo fmt --check (when the rustfmt component is installed)
#   5. cargo clippy -- -D warnings (when the clippy component is installed)
#
# Run from anywhere inside the repository; fully offline.
set -euo pipefail

cd "$(dirname "$0")/.."

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== cargo doc --no-deps =="
cargo doc --no-deps

if cargo fmt --version >/dev/null 2>&1; then
  echo "== cargo fmt --check =="
  cargo fmt --check
else
  echo "== cargo fmt --check: rustfmt not installed, skipping =="
fi

if cargo clippy --version >/dev/null 2>&1; then
  echo "== cargo clippy -- -D warnings =="
  cargo clippy --all-targets -- -D warnings
else
  echo "== cargo clippy: clippy not installed, skipping =="
fi

echo "verify: OK"
