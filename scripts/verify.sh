#!/usr/bin/env bash
# Tier-1 verification gate for the NVM-in-Cache reproduction:
#   1. release build (lib + repro bin + examples + benches)
#   2. full test suite
#   3. rustdoc build (crate carries #![warn(missing_docs)])
#
# Run from anywhere inside the repository; fully offline.
set -euo pipefail

cd "$(dirname "$0")/.."

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== cargo doc --no-deps =="
cargo doc --no-deps

echo "verify: OK"
